"""Docs smoke: every shell command quoted in the given markdown files
must resolve against the tree it documents.

``python tools/check_docs.py README.md docs/benchmarks.md``

For each fenced ``bash``/``sh``/``text``-less code block, every
``python`` invocation is checked statically (nothing is executed):

  * ``python -m pkg.mod``   — the module must exist under ``src/`` or
    the repo root (package ``__init__``/``__main__`` aware);
  * ``python path/to.py``   — the script file must exist;
  * ``--flags``             — every long option passed must appear in
    an ``add_argument("--...")`` call in the target module's source
    (following one ``from X import main`` delegation hop, the
    ``examples/*.py`` thin-driver idiom);
  * ``pip install -r F``    — the requirements file must exist.

Relative markdown links ``[text](path)`` must also resolve on disk.
Exits non-zero listing every stale command, so a renamed flag or
moved module fails CI instead of rotting in the docs.
"""

from __future__ import annotations

import os
import re
import shlex
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE_RE = re.compile(r"^```(\w*)\s*$")
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)")
ADD_ARG_RE = re.compile(r"add_argument\(\s*[\"'](--[\w-]+)[\"']")
DELEGATE_RE = re.compile(r"^from\s+([\w.]+)\s+import\s+main\b", re.M)

SHELL_LANGS = {"", "bash", "sh", "shell", "console"}


def module_file(dotted: str) -> str | None:
    """Resolve ``pkg.mod`` to a source file under src/ or the repo
    root without importing anything (imports would drag in jax)."""
    rel = dotted.replace(".", os.sep)
    for root in (os.path.join(REPO, "src"), REPO):
        for cand in (rel + ".py",
                     os.path.join(rel, "__main__.py"),
                     os.path.join(rel, "__init__.py")):
            p = os.path.join(root, cand)
            if os.path.isfile(p):
                return p
    return None


def declared_flags(path: str, hops: int = 1) -> set:
    """Long options the module's argparse setup declares; follows one
    ``from X import main`` delegation (the examples/ driver idiom)."""
    with open(path, encoding="utf-8") as f:
        src = f.read()
    flags = set(ADD_ARG_RE.findall(src))
    if not flags and hops:
        m = DELEGATE_RE.search(src)
        if m:
            target = module_file(m.group(1))
            if target:
                flags = declared_flags(target, hops - 1)
    return flags


def shell_commands(md_path: str):
    """Yield (lineno, command) for each statement in shell fences."""
    lang, buf = None, []
    with open(md_path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            m = FENCE_RE.match(line)
            if m:
                lang = None if lang is not None else m.group(1)
                continue
            if lang is None or lang not in SHELL_LANGS:
                continue
            stmt = line.split("#", 1)[0].strip()
            if stmt:
                yield i, stmt


def check_command(stmt: str) -> list:
    """Return a list of problem strings for one shell statement."""
    try:
        toks = shlex.split(stmt)
    except ValueError as exc:
        return [f"unparseable: {exc}"]
    while toks and ("=" in toks[0] and not toks[0].startswith("-")):
        toks = toks[1:]           # strip ENV=VAL prefixes
    if not toks:
        return []
    prog = os.path.basename(toks[0])

    if prog == "pip":
        probs = []
        for j, t in enumerate(toks):
            if t == "-r" and j + 1 < len(toks) \
                    and not os.path.isfile(os.path.join(REPO, toks[j + 1])):
                probs.append(f"missing requirements file {toks[j + 1]}")
        return probs
    if not prog.startswith("python"):
        return []                 # only python invocations are gated

    args = toks[1:]
    target = None
    if args and args[0] == "-m":
        if len(args) < 2:
            return ["python -m with no module"]
        target = module_file(args[1])
        if target is None:
            # third-party entry point (e.g. pytest): importable is
            # enough; its flags aren't ours to gate
            import importlib.util
            sys.path.insert(0, os.path.join(REPO, "src"))
            try:
                found = importlib.util.find_spec(args[1]) is not None
            except (ImportError, ValueError):
                found = False
            finally:
                sys.path.pop(0)
            return [] if found else \
                [f"module {args[1]} not found (repo or site-packages)"]
        rest = args[2:]
    elif args and not args[0].startswith("-"):
        path = os.path.join(REPO, args[0])
        if not os.path.isfile(path):
            return [f"script {args[0]} does not exist"]
        target = path
        rest = args[1:]
    else:
        return []

    used = {a.split("=", 1)[0] for a in rest if a.startswith("--")}
    if not used:
        return []
    known = declared_flags(target)
    if not known:                 # module takes no argparse flags
        return [f"{os.path.relpath(target, REPO)} declares no flags but "
                f"docs pass {sorted(used)}"]
    return [f"unknown flag {f} for {os.path.relpath(target, REPO)}"
            for f in sorted(used - known)]


def check_links(md_path: str) -> list:
    base = os.path.dirname(os.path.abspath(md_path))
    probs = []
    with open(md_path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            for ref in LINK_RE.findall(line):
                if "://" in ref or ref.startswith("mailto:"):
                    continue
                if not os.path.exists(os.path.join(base, ref)):
                    probs.append((i, f"broken link: {ref}"))
    return probs


def main(argv) -> int:
    files = argv or ["README.md", "docs/benchmarks.md"]
    failures, n_cmds = [], 0
    for md in files:
        path = os.path.join(REPO, md) if not os.path.isabs(md) else md
        if not os.path.isfile(path):
            failures.append(f"{md}: file missing")
            continue
        for lineno, stmt in shell_commands(path):
            n_cmds += 1
            for prob in check_command(stmt):
                failures.append(f"{md}:{lineno}: {prob}    [{stmt}]")
        for lineno, prob in check_links(path):
            failures.append(f"{md}:{lineno}: {prob}")
    if failures:
        print(f"check_docs: {len(failures)} stale reference(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"check_docs: {n_cmds} commands + all relative links resolve "
          f"across {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
