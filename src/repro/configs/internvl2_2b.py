"""InternVL2-2B language backbone (InternLM2-1.8B): vision frontend stubbed as 256 patch embeddings per image.
Source: arXiv:2404.16821
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='internvl2-2b',
        family='vlm',
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=92553,
        n_frontend_tokens=256,
        rope_theta=1000000.0,
        source='arXiv:2404.16821',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='internvl2-smoke',
        family='vlm',
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_frontend_tokens=8,
    )
