"""HSTU GR backbone (the paper's own model family): 8 layers, d=256, fp32 KV cache -> 32MB psi at 2K tokens (paper Table 1).
Source: arXiv:2402.17152 (HSTU); RelayGR paper Table 1
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='hstu-gr',
        family='dense',
        hstu=True,
        n_layers=8,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1024,
        vocab=100000,
        n_tasks=1,
        dtype='float32',
        rope_theta=10000.0,
        source='arXiv:2402.17152',
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='hstu-smoke',
        family='dense',
        hstu=True,
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab=512,
        n_tasks=1,
        dtype='float32',
    )
