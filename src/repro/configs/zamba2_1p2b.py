"""Zamba2-1.2B: Mamba2 backbone + shared GQA attention block every 6 layers (per-invocation LoRA).
Source: arXiv:2411.15242
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='zamba2-1.2b',
        family='hybrid',
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        attn_every=6,
        rope_theta=10000.0,
        source='arXiv:2411.15242',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='zamba2-smoke',
        family='hybrid',
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        attn_every=2,
    )
