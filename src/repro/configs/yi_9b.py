"""Yi-9B: llama-architecture dense GQA decoder.
Source: arXiv:2403.04652
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='yi-9b',
        family='dense',
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab=64000,
        rope_theta=10000.0,
        source='arXiv:2403.04652',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='yi-smoke',
        family='dense',
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab=512,
    )
