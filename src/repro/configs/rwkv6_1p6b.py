"""RWKV6 (Finch) 1.6B: attention-free, data-dependent decay.
Source: arXiv:2404.05892
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='rwkv6-1.6b',
        family='ssm_rwkv6',
        n_layers=24,
        d_model=2048,
        d_ff=7168,
        vocab=65536,
        glu=False,
        act='relu',
        rope_theta=0.0,
        source='arXiv:2404.05892',
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='rwkv6-smoke',
        family='ssm_rwkv6',
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        glu=False,
        act='relu',
        rope_theta=0.0,
    )
