"""DBRX-132B: MoE, 16 experts top-4, fine-grained.
Source: hf:databricks/dbrx-base
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='dbrx-132b',
        family='moe',
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        n_shared_experts=0,
        top_k=4,
        d_expert=10752,
        rope_theta=500000.0,
        source='hf:databricks/dbrx-base',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='dbrx-smoke',
        family='moe',
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab=512,
        n_experts=4,
        n_shared_experts=0,
        top_k=2,
        d_expert=128,
    )
