"""SeamlessM4T-large-v2 transformer backbone: enc-dec, audio frontend stubbed (frame embeddings provided by input_specs).
Source: arXiv:2308.11596
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='seamless-m4t-large-v2',
        family='encdec',
        n_layers=24,
        n_enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab=256206,
        n_frontend_tokens=1536,
        rope_theta=10000.0,
        source='arXiv:2308.11596',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='seamless-smoke',
        family='encdec',
        n_layers=2,
        n_enc_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_frontend_tokens=8,
    )
