"""Per-architecture configs (assigned pool + the paper's HSTU-GR)."""
