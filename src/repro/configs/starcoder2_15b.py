"""StarCoder2-15B: dense GQA decoder, RoPE, sliding-window 4096.
Source: arXiv:2402.19173
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='starcoder2-15b',
        family='dense',
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        glu=False,
        act='gelu',
        rope_theta=100000.0,
        sliding_window=4096,
        source='arXiv:2402.19173',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='starcoder2-smoke',
        family='dense',
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab=512,
        glu=False,
        act='gelu',
        rope_theta=100000.0,
        sliding_window=64,
    )
