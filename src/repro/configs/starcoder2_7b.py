"""StarCoder2-7B: dense GQA decoder, RoPE, sliding-window 4096. 36 heads do not divide the 16-way model axis; attention degrades to replicated TP (see DESIGN.md).
Source: arXiv:2402.19173
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='starcoder2-7b',
        family='dense',
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab=49152,
        glu=False,
        act='gelu',
        rope_theta=100000.0,
        sliding_window=4096,
        source='arXiv:2402.19173',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
        head_pad=48,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='starcoder2-7b-smoke',
        family='dense',
        n_layers=2,
        d_model=288,
        n_heads=9,
        n_kv_heads=3,
        head_dim=32,
        d_ff=576,
        vocab=512,
        glu=False,
        act='gelu',
        rope_theta=100000.0,
        sliding_window=64,
    )
