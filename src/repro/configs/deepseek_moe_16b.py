"""DeepSeekMoE-16B: fine-grained MoE, 64 routed experts top-6 + 2 shared experts.
Source: arXiv:2401.06066
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='deepseek-moe-16b',
        family='moe',
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=102400,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_expert=1408,
        rope_theta=10000.0,
        source='arXiv:2401.06066',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='deepseek-moe-smoke',
        family='moe',
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=64,
        vocab=512,
        n_experts=4,
        n_shared_experts=1,
        top_k=2,
        d_expert=64,
    )
