"""Qwen3-4B: dense GQA decoder with qk-norm, explicit head_dim=128.
Source: hf:Qwen/Qwen3-8B
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='qwen3-4b',
        family='dense',
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        source='hf:Qwen/Qwen3-8B',
        attn_q_chunk=2048,  # perf hillclimb (EXPERIMENTS.md §Perf)
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers,
    d_model<=512, <=4 experts)."""
    return ModelConfig(
        name='qwen3-smoke',
        family='dense',
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab=512,
        qk_norm=True,
        rope_theta=1000000.0,
    )
