import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, partitions and compiles for the production meshes.

For each combination this script:
  1. builds the model and the step function (train_step for train shapes,
     prefill/serve_step for inference shapes),
  2. lowers + compiles it under the 16x16 single-pod mesh AND the
     2x16x16 multi-pod mesh with explicit in_shardings,
  3. records memory_analysis / cost_analysis / collective traffic
     (parsed from the partitioned HLO) into a JSON artifact consumed by
    the roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md).

Failures (sharding mismatch, OOM at compile, unsupported collective) are
system bugs: the run exits non-zero listing them.
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step
from repro.models import ARCH_IDS, INPUT_SHAPES, build_model, get_config
from repro.models.partitioning import Rules, logical_rules

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_collectives(hlo_text: str):
    """Sum output-operand bytes of every collective op in partitioned HLO."""
    stats = {c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%x = bf16[8,128]{1,0} all-gather(...)" / fusion lines excluded
        m = re.match(r"^[%\w.\-]+ = \(?([a-z0-9]+)\[([\d,]*)\]", s)
        if not m:
            continue
        op = None
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", s):
                op = c
                break
        if op is None or f"{op}-done(" in s:
            continue
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        stats[op]["count"] += 1
        stats[op]["bytes"] += n * nbytes
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _should_skip(cfg, shape):
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is full-attention with no sliding window "
                "(see DESIGN.md)")
    return None


def run_one(arch: str, shape_name: str, multi_pod: bool,
            fsdp: str = "auto", donate: bool = True,
            overrides=None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    skip = _should_skip(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "params": cfg.param_count()}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    zero2 = fsdp == "zero2"
    if fsdp == "auto":
        # keep weights under ~25% of chip HBM without FSDP; else ZeRO-3
        itemsize = 4 if cfg.dtype == "float32" else 2
        per_chip = cfg.param_count() * itemsize / mesh.shape["model"]
        use_fsdp = per_chip > 4e9
    else:
        use_fsdp = fsdp == "on"
    rec["fsdp"] = "zero2" if zero2 else bool(use_fsdp)

    ovr = dict(overrides or {})
    if shape.kind == "decode" and shape.global_batch == 1:
        ovr.setdefault("kv_seq", "data")

    t0 = time.time()
    with logical_rules(mesh, overrides=ovr, fsdp=use_fsdp) as rules:
        fn, arg_sds, arg_axes = make_step(model, shape, zero2=zero2)
        from repro.launch.flops import step_flops
        rec["jaxpr_flops_global"] = float(step_flops(fn, arg_sds))
        in_shardings = jax.tree.map(
            lambda ax, sds: jax.NamedSharding(
                mesh, rules.spec(ax, shape=sds.shape)),
            arg_axes, arg_sds,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        # donate params+opt state (train) / KV cache (decode): the update
        # is in-place at the XLA level, halving resident state
        donate_args = ((0, 1) if shape.kind == "train"
                       else (1,) if shape.kind == "decode" else ())
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             donate_argnums=donate_args if donate else ())
            lowered = jitted.lower(*arg_sds)
            compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # backend-dependent
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and (
                           k in ("flops", "bytes accessed")
                           or k.startswith("bytes accessed"))}
    except Exception as e:
        rec["cost"] = {"error": str(e)}
    hlo = compiled.as_text()
    rec["collectives"] = _parse_collectives(hlo)
    rec["n_chips"] = n_chips
    rec["status"] = "ok"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--fsdp", default="auto",
                    choices=["auto", "on", "off", "zero2"])
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--tag", default="baseline",
                    help="artifact tag (perf iterations use new tags)")
    ap.add_argument("--override", action="append", default=[],
                    help="logical=mesh axis rule override, e.g. kv_seq=data")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = tuple(v.split(",")) if "," in v else v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_one(arch, shape, mp, fsdp=args.fsdp,
                                  overrides=overrides)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc(limit=8)}
                    failures.append(tag)
                path = outdir / f"{args.tag}__{tag}.json"
                path.write_text(json.dumps(rec, indent=1))
                flops = rec.get("cost", {}).get("flops", 0)
                print(f"{rec['status']:8s} {tag:55s} "
                      f"compile={rec.get('compile_s', 0):6.1f}s "
                      f"GFLOPs={flops / 1e9:12.1f} "
                      f"coll={rec.get('collectives', {}).get('total_bytes', 0) / 1e6:10.1f}MB",
                      flush=True)
                if rec["status"] == "FAILED":
                    print(rec["error"], flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
