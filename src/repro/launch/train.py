"""Training launcher: ``python -m repro.launch.train --arch hstu-gr``.

Runs the real data pipeline -> jitted train_step -> checkpoint loop on
whatever devices are visible (CPU here; a TPU slice in production —
pass --mesh to enable the production sharding rules).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
from repro.launch.steps import make_train_step
from repro.models import build_model, get_config
from repro.models.config import InputShape
from repro.training import checkpoint
from repro.training import optimizer as opt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hstu-gr")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"family={cfg.family}")

    shape = InputShape("cli", args.seq, args.batch, "train")
    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=20,
                            total_steps=args.steps)
    step_fn, _, _ = make_train_step(model, shape, adamw)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)
    store = UserBehaviorStore(WorkloadConfig(vocab=cfg.vocab))
    batches = store.train_batches(args.batch, args.seq)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        if cfg.family == "vlm":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        params, state, m = jstep(params, state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"grad_norm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, params, state, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
