"""Jaxpr-level FLOP / traffic accounting for the dry-run roofline.

XLA's CPU-backend ``compiled.cost_analysis()`` counts a ``while`` body
once, regardless of trip count, so scan-over-layers models are
undercounted by ~n_layers (verified: scan of 10 matmuls reports 1
matmul).  This module walks the closed jaxpr of the step function
instead: ``scan`` primitives carry their ``length``, so dot/conv FLOPs
inside layer stacks, chunked SSM scans and remat-recomputed bodies are
multiplied exactly.  Elementwise FLOPs are ignored (matmul-dominated
workloads; consistent with how MODEL_FLOPS = 6*N*D is defined).

Counted: dot_general, conv_general_dilated.  Recursed: scan (x length),
while (x1, unknown trips), pjit/closed_call/remat/custom_*derivatives.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Any

import jax
import numpy as np
from jax import core


def _dot_flops(eqn) -> float:
    (lhs, rhs), out = eqn.invars, eqn.outvars[0]
    dn = eqn.params["dimension_numbers"]
    (lc, rc), _ = dn
    contract = 1
    for d in lc:
        contract *= lhs.aval.shape[d]
    out_elems = float(np.prod(out.aval.shape)) if out.aval.shape else 1.0
    return 2.0 * out_elems * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    # flops = 2 * out_elems * (kernel spatial * in_features)
    k_elems = float(np.prod(rhs.shape))
    out_spatial = float(np.prod(out.shape))
    cout = rhs.shape[dn.rhs_spec[0]]
    return 2.0 * out_spatial * k_elems / max(cout, 1)


def count_jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            inner = count_jaxpr_flops(eqn.params["jaxpr"].jaxpr)
            total += eqn.params["length"] * inner
        elif name == "while":
            total += count_jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        elif name == "shard_map":
            # the inner jaxpr is per-shard: multiply by the number of
            # shards (all mapped devices do distinct expert/data work)
            inner = eqn.params["jaxpr"]
            inner = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            n_shards = eqn.params["mesh"].size
            total += n_shards * count_jaxpr_flops(inner)
        elif name in ("pjit", "closed_call", "core_call", "remat2",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr"):
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    total += count_jaxpr_flops(
                        sub.jaxpr if hasattr(sub, "jaxpr") else sub)
                    break
        elif name == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                total += max(count_jaxpr_flops(b.jaxpr) for b in branches)
    return total


def step_flops(fn, arg_sds) -> float:
    """Total (global, unpartitioned) dot/conv FLOPs of one step."""
    jaxpr = jax.make_jaxpr(fn)(*arg_sds)
    return count_jaxpr_flops(jaxpr.jaxpr)
