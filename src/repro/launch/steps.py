"""train_step / serve_step factories — the functions the launcher jits,
the dry-run lowers, and the benchmarks time.

Each factory returns ``(fn, arg_specs, arg_axes, out_axes_hint)`` where
``arg_specs`` is a tuple of ShapeDtypeStruct pytrees (positional args of
``fn``) and ``arg_axes`` the matching logical-axes pytrees used to build
``in_shardings`` for a concrete mesh.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import InputShape
from repro.training import optimizer as opt


def make_train_step(model, shape: InputShape, adamw: opt.AdamWConfig = None,
                    zero2: bool = False):
    adamw = adamw or opt.AdamWConfig()

    def train_step(params, state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, state, om = opt.apply_updates(adamw, params, grads, state)
        metrics = dict(metrics, **om, loss=loss)
        return params, state, metrics

    p_sds = model.abstract_params()
    p_axes = model.param_axes()
    s_sds = opt.abstract_state(p_sds)
    s_axes = opt.state_axes(p_axes, zero2=zero2)
    b_sds = model.batch_specs(shape)
    b_axes = model.batch_axes(shape)
    return train_step, (p_sds, s_sds, b_sds), (p_axes, s_axes, b_axes)


def make_prefill_step(model, shape: InputShape):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    p_sds = model.abstract_params()
    p_axes = model.param_axes()
    b_sds = model.batch_specs(shape)
    b_axes = model.batch_axes(shape)
    return prefill_step, (p_sds, b_sds), (p_axes, b_axes)


def make_serve_step(model, shape: InputShape):
    """Decode: ONE new token against a KV cache / recurrent state of
    ``shape.seq_len`` tokens."""

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    p_sds = model.abstract_params()
    p_axes = model.param_axes()
    c_sds, c_axes = model.cache_specs(shape.global_batch, shape.seq_len)
    b_sds = model.batch_specs(shape)
    b_axes = model.batch_axes(shape)
    return serve_step, (p_sds, c_sds, b_sds), (p_axes, c_axes, b_axes)


def make_step(model, shape: InputShape, zero2: bool = False):
    if shape.kind == "train":
        return make_train_step(model, shape, zero2=zero2)
    if shape.kind == "prefill":
        return make_prefill_step(model, shape)
    return make_serve_step(model, shape)


def input_specs(model, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of a step —
    weak-type-correct, shardable, no device allocation (the dry-run
    contract).  Train shapes: {tokens, labels, (frontend/frames)};
    serve shapes additionally include the KV-cache/state stand-ins."""
    _, arg_sds, _ = make_step(model, shape)
    return arg_sds
