"""Production mesh construction.

Target: TPU v5e pods — 256 chips per pod arranged (data=16, model=16);
multi-pod adds a leading "pod" axis (2 pods = 512 chips) used for data
parallelism across pods (batch shards over ("pod", "data")).

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# --- hardware constants (TPU v5e) used by the roofline analysis -----------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link
CHIP_HBM_BYTES = 16e9         # v5e HBM capacity
