"""Serving launcher: the end-to-end RelayGR driver (paper's kind).

``python -m repro.launch.serve --requests 200`` boots a live RelayGR
service (real HSTU compute on the local device), replays a synthetic
request stream through the shared event-driven relay runtime —
retrieval -> trigger -> affinity routing -> ranking — and reports hit
rates + latency components.  ``--sim`` switches to the virtual-clock
cluster simulation at production QPS.  ``--batched`` swaps in the
registered ``batched`` executor: rank requests micro-batch through the
per-instance aggregator into single bucketed jitted launches, with the
bucket x batch-size jit entries pre-warmed from the sampled arrival
stream so compiles leave the P99 path.  All modes drive the identical
``RelayRuntime`` state machine (repro.core.runtime); only the clock and
the executor differ.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (BatchingConfig, ClusterConfig, GRCostModel,
                        LiveExecutor, RelayGRService, TriggerConfig,
                        get_executor, relay_config)
from repro.data.synthetic import (UserBehaviorStore, WorkloadConfig,
                                  request_stream)
from repro.models import build_model, get_config


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hstu-gr")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--sim", action="store_true",
                    help="cluster-scale discrete-event simulation")
    ap.add_argument("--batched", action="store_true",
                    help="live continuous micro-batching "
                         "(registered 'batched' executor)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--batch-wait-ms", type=float, default=2.0)
    ap.add_argument("--page-tokens", type=int, default=0,
                    help=">0 stores psi in a paged HBM pool and ranks "
                         "through the rank_with_pages path")
    ap.add_argument("--segments", action="store_true",
                    help="beyond-prefix reuse: the stream attaches per-"
                         "user candidate-independent seg_lens and the "
                         "side path caches them alongside the prefix "
                         "(implies a paged window; defaults "
                         "--page-tokens to 64 when unset)")
    ap.add_argument("--device-pool", action="store_true",
                    help="keep the paged KV pool device-resident: "
                         "inserts/reloads scatter only fresh pages "
                         "(donated in-place update) and rank launches "
                         "pass the pool by reference — per-launch H2D "
                         "re-ship drops to zero (implies a paged "
                         "window; defaults --page-tokens to 64 when "
                         "unset)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="stripe the instance pools over N hosts; keyed "
                         "traffic routes owner-map -> per-host ring")
    ap.add_argument("--prefill-hosts", type=int, default=0,
                    help=">0 disaggregates the pre-infer side path onto "
                         "dedicated hosts; psi ships cross-host to its "
                         "owning rank instance over the NIC fabric")
    ap.add_argument("--cold-budget", type=float, default=0.0,
                    help=">0 adds a host-local cold tier (SSD / remote "
                         "psi store) of this many bytes under DRAM: "
                         "evictions demote instead of dropping, and a "
                         "cold-resident user's admission starts an async "
                         "cold->DRAM promotion")
    ap.add_argument("--dram-budget", type=float, default=500e9,
                    help="per-host DRAM expander budget in bytes")
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 serves N tenants off the one fleet: every "
                         "memory tier is partitioned into per-tenant "
                         "byte/page quotas (a tenant can only evict its "
                         "own entries), admission gets per-tenant token "
                         "buckets, and stats report per-tenant ledgers")
    args = ap.parse_args(argv)
    if (args.segments or args.device_pool) and not args.page_tokens:
        args.page_tokens = 64  # segment spans / device pool need pages

    cfg = get_config(args.arch, smoke=args.smoke and not args.sim)
    cost = GRCostModel(get_config(args.arch))

    if args.sim:
        from repro.serving.simulator import run_sim
        store = UserBehaviorStore()
        arr = request_stream(store, args.qps, args.requests / args.qps,
                             segments=args.segments, tenants=args.tenants)
        s = run_sim(relay_config(
            trigger=TriggerConfig(n_instances=10),
            cluster=ClusterConfig(hosts=args.hosts,
                                  prefill_hosts=args.prefill_hosts,
                                  page_tokens=args.page_tokens,
                                  segments=args.segments,
                                  device_pool=args.device_pool,
                                  dram_budget_bytes=args.dram_budget,
                                  cold_budget_bytes=args.cold_budget,
                                  tenants=args.tenants)),
            cost, arr)
        print(json.dumps(s, indent=1))
        return s

    # live mode: real JAX compute, small instance pool
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=64, incr_len=16, len_mu=6.8, len_sigma=0.9,
        max_len=2048))
    # a paged window preallocates its pool buffer up front (that is the
    # point: fixed pages, zero fragmentation) — bound it to a host-
    # friendly size for the local smoke instead of the 16 GB default
    hbm_bytes = 128e6 if args.page_tokens else 16e9
    relay_cfg = relay_config(
        trigger=TriggerConfig(n_instances=4, r2=0.5,
                              rank_p99_budget_ms=20.0),
        cluster=ClusterConfig(max_batch=args.max_batch if args.batched
                              else 0,
                              batch_wait_ms=args.batch_wait_ms,
                              page_tokens=args.page_tokens,
                              segments=args.segments,
                              device_pool=args.device_pool,
                              hosts=args.hosts,
                              prefill_hosts=args.prefill_hosts,
                              hbm_cache_bytes=hbm_bytes,
                              dram_budget_bytes=args.dram_budget,
                              cold_budget_bytes=args.cold_budget,
                              tenants=args.tenants))

    def report(results):
        hits, lat = {}, []
        for r in results:
            assert abs(r.latency_ms - sum(r.components.values())) < 1e-6
            hits[r.hit.value] = hits.get(r.hit.value, 0) + 1
            lat.append(r.components["rank"])
        print(f"requests={len(results)} hits={hits}")
        print(f"rank compute ms: p50={np.percentile(lat, 50):.1f} "
              f"p99={np.percentile(lat, 99):.1f}")
        return hits

    def report_tenants(svc):
        if args.tenants <= 1:
            return
        ten = svc.stats()["tenants"]
        print(json.dumps({"tenants": ten}, indent=1))
        # isolation invariants the live smoke leans on: every tenant's
        # admission ledger saw traffic, and no tenant ever evicted
        # another tenant's entry out of any tier
        assert ten["cross_tenant_evictions"] == 0, (
            f"tenant partition violated: "
            f"{ten['cross_tenant_evictions']} cross-tenant evictions")
        assert all(ten["admission"].get(t, {}).get("assessed", 0) > 0
                   for t in range(args.tenants)), (
            "per-tenant admission ledger not populated: "
            f"{ten['admission']}")

    def report_h2d(svc):
        if not args.page_tokens:
            return
        h2d = svc.stats()["h2d"]
        print(json.dumps({"h2d": h2d}, indent=1))
        if args.device_pool:
            # the whole point of the device-resident pool: rank
            # launches pass the pool by reference, so a single re-ship
            # is a wiring regression
            assert h2d["device_resident"], "device pool not wired"
            assert h2d["launch_reships"] == 0, (
                f"device-pool launch re-shipped the pool "
                f"{h2d['launch_reships']}x")
            assert h2d["bytes_scattered"] > 0

    if args.batched:
        # one shared executor across the pool -> one jit cache; pre-warm
        # the (bucket, batch) grid the sampled stream will actually hit
        ex = get_executor("batched")(
            model, params, store, cost=cost,
            batching=BatchingConfig(max_batch=args.max_batch,
                                    max_wait_ms=args.batch_wait_ms),
            page_tokens=args.page_tokens, segments=args.segments,
            device_pool=args.device_pool)
        arrivals = []
        for i, (t, meta) in enumerate(request_stream(
                store, args.qps, 1e9, refresh_prob=0.2,
                segments=args.segments, tenants=args.tenants)):
            if i >= args.requests:
                break
            arrivals.append((t, meta))
        pool_pages = 0
        if args.page_tokens:
            # the executor owns the page geometry; deriving the pool
            # size from ITS layout keeps the warmed rank_with_pages jit
            # key (pool-buffer shape) identical to the serving store's
            pool_pages = (int(relay_cfg.cluster.hbm_cache_bytes)
                          // ex.page_layout.page_bytes)
        warmed = ex.warmup([m.prefix_len for _, m in arrivals],
                           batch_sizes=range(1, args.max_batch + 1),
                           incr_len=store.cfg.incr_len,
                           n_items=store.cfg.n_items,
                           pool_pages=pool_pages)
        print(f"warmed {len(warmed)} (bucket, batch) jit entries: "
              f"{sorted({k[:2] for k in warmed})}")
        svc = RelayGRService(relay_cfg, cost,
                             executor_factory=lambda name: ex)
        results = []
        rt = svc.runtime
        for t, meta in arrivals:
            rt.schedule(t, "arrival", meta=meta, sink=results.append)
        rt.drain()
        hits = report(results)
        batch = {n: i.batcher.stats for n, i in svc.instances.items()
                 if i.batcher is not None and i.batcher.stats["requests"]}
        print(json.dumps({"batch": batch}, indent=1))
        report_tenants(svc)
        report_h2d(svc)
        return hits
    svc = RelayGRService(
        relay_cfg, cost,
        executor_factory=lambda name: LiveExecutor(
            model, params, store, page_tokens=args.page_tokens,
            segments=args.segments, device_pool=args.device_pool))
    results = []
    for i, (t, meta) in enumerate(request_stream(
            store, args.qps, 1e9, refresh_prob=0.2,
            segments=args.segments, tenants=args.tenants)):
        if i >= args.requests:
            break
        results.append(svc.submit(meta, now=t))
    hits = report(results)
    print(json.dumps(svc.stats()["trigger"], indent=1))
    if args.prefill_hosts:
        print(json.dumps({"shipping": svc.stats()["shipping"]}, indent=1))
    if args.cold_budget:
        print(json.dumps({"cold": svc.stats()["cold"]}, indent=1))
    report_tenants(svc)
    report_h2d(svc)
    return hits


if __name__ == "__main__":
    main()
