"""Serving launcher: the end-to-end RelayGR driver (paper's kind).

``python -m repro.launch.serve --requests 200`` boots a live RelayGR
service (real HSTU compute on the local device), replays a synthetic
request stream through the shared event-driven relay runtime —
retrieval -> trigger -> affinity routing -> ranking — and reports hit
rates + latency components.  ``--sim`` switches to the virtual-clock
cluster simulation at production QPS.  Both modes drive the identical
``RelayRuntime`` state machine (repro.core.runtime); only the clock and
the executor differ.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (ClusterConfig, GRCostModel, LiveExecutor,
                        RelayGRService, TriggerConfig, relay_config)
from repro.data.synthetic import (UserBehaviorStore, WorkloadConfig,
                                  request_stream)
from repro.models import build_model, get_config


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hstu-gr")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--sim", action="store_true",
                    help="cluster-scale discrete-event simulation")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke and not args.sim)
    cost = GRCostModel(get_config(args.arch))

    if args.sim:
        from repro.serving.simulator import run_sim
        store = UserBehaviorStore()
        arr = request_stream(store, args.qps, args.requests / args.qps)
        s = run_sim(relay_config(trigger=TriggerConfig(n_instances=10)),
                    cost, arr)
        print(json.dumps(s, indent=1))
        return s

    # live mode: real JAX compute, small instance pool
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=64, incr_len=16, len_mu=6.8, len_sigma=0.9,
        max_len=2048))
    svc = RelayGRService(
        relay_config(trigger=TriggerConfig(n_instances=4, r2=0.5,
                                           rank_p99_budget_ms=20.0),
                     cluster=ClusterConfig()),
        cost,
        executor_factory=lambda name: LiveExecutor(model, params, store))
    hits, lat = {}, []
    for i, (t, meta) in enumerate(request_stream(
            store, args.qps, 1e9, refresh_prob=0.2)):
        if i >= args.requests:
            break
        r = svc.submit(meta, now=t)
        assert abs(r.latency_ms - sum(r.components.values())) < 1e-6
        hits[r.hit.value] = hits.get(r.hit.value, 0) + 1
        lat.append(r.components["rank"])
    print(f"requests={args.requests} hits={hits}")
    print(f"rank compute ms: p50={np.percentile(lat, 50):.1f} "
          f"p99={np.percentile(lat, 99):.1f}")
    print(json.dumps(svc.stats()["trigger"], indent=1))
    return hits


if __name__ == "__main__":
    main()
