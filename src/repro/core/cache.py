"""HBM-resident prefix-cache store — the sliding lifecycle window.

Admitted prefix caches psi(u) are inserted by pre-inference, consumed by
ranking within the request lifecycle T_life, and evicted as new admitted
users arrive (paper Fig. 10).  The store enforces the byte budget
``r1 * HBM`` from invariant I2; admission control (trigger) is what makes
the budget sufficient for survival — the store itself just implements
the window and reports violations (an admitted-but-evicted-before-
consumption cache counts as a ``premature_eviction``; under a correctly
configured trigger this stays at zero, and the property tests assert it).

Accounting is conserved: every entry that ever entered the window is
either still live or counted in ``evictions`` (budget pressure,
same-user refresh, or an explicit ``pop``), so

    stats["inserts"] == live_count + stats["evictions"]

holds after any interleaving (tests/test_cache_properties.py).

In live mode ``CacheEntry.value`` holds the real per-layer KV pytree
psi(u) — (K, V) arrays of shape (L, B, P, H, D) as produced by
``HSTUModel.prefill`` — which the batched executor pads and stacks
directly (``repro.serving.batching.pad_psi``); ``kv_nbytes`` sizes such
a pytree for budget accounting.

An insert that can never fit (``nbytes`` over the whole budget) is
REJECTED up front: the window is left untouched, the rejection is
counted in ``stats["rejected_inserts"]``, and the runtime observes the
absence as a miss — it must never believe psi is resident.

``PagedHBMStore`` is the block-granular variant (``ClusterConfig.
page_tokens > 0``): same window semantics, but psi is stored in a
fixed-size page pool (``repro.core.paging``) so mixed prefix lengths
share the budget without fragmentation, eviction can free just the tail
pages of a consumed DRAM-backed entry, and a later reload *resumes*
from the still-resident head pages instead of restarting.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .paging import (DevicePagePool, PageLayout, PagePool, PagedPsi,
                     ceil_div, slice_into_pages)
from .types import CacheState


def kv_nbytes(value: Any) -> int:
    """Bytes held by a KV pytree (nested tuples/lists/dicts of arrays);
    scalar/stub values (the sim executor's psi token) count as zero."""
    if isinstance(value, (tuple, list)):
        return sum(kv_nbytes(v) for v in value)
    if isinstance(value, dict):
        return sum(kv_nbytes(v) for v in value.values())
    shape = getattr(value, "shape", None)
    dtype = getattr(value, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape)) * np.dtype(dtype).itemsize


@dataclasses.dataclass
class CacheEntry:
    user_id: int
    value: Any                 # pytree of per-layer KV (or a byte-size stub)
    nbytes: int
    created_at: float
    state: CacheState = CacheState.HBM
    consumed: bool = False
    prefix_len: int = 0
    dram_backed: bool = False  # a DRAM spill copy exists (set by runtime)
    # paged-store residency: tokens still page-resident (== prefix_len
    # when fully resident; less after a partial tail eviction) and the
    # tokens a pending DRAM->HBM reload must actually stream
    tokens_resident: int = 0
    reload_tokens: Optional[int] = None
    page_table: Optional[np.ndarray] = None   # (slabs, n_pages) int32
    # beyond-prefix segment reuse: ordered (global_start, valid_len)
    # cached spans — None for prefix-only entries.  In the paged store a
    # segmented entry pads EVERY span to whole pages and ``prefix_len``
    # holds the padded total, so the page math (entry_pages, resume,
    # partial tail eviction) is span-agnostic; ``spans`` preserves the
    # true layout for the kernel's position/validity tables.
    spans: Optional[Tuple[Tuple[int, int], ...]] = None
    # cold-tier revival marker: set when this copy was promoted out of
    # the cold store; the first rank it serves classifies as COLD_HIT
    # (then the flag clears — later lifecycles are ordinary warm hits)
    cold_sourced: bool = False
    # multi-tenant serving: the tenant this psi belongs to.  Rides the
    # entry through every tier (HBM -> DRAM -> cold) and every copy
    # (spill / demotion / handoff), so partition enforcement never has
    # to guess ownership.  0 for single-tenant deployments.
    tenant: int = 0


def tenant_ledger(quota: Optional[Dict[int, int]], *keys: str
                  ) -> Optional[Dict[int, Dict[str, int]]]:
    """Per-tenant counter block for a store: one zeroed dict of ``keys``
    per tenant in the quota map, or None when the store is untenanted
    (single-tenant deployments build no per-tenant machinery at all)."""
    if quota is None:
        return None
    return {int(t): {k: 0 for k in keys} for t in quota}


class HBMCacheStore:
    """FIFO sliding-window cache under a byte budget (single instance).

    With a ``tenant_quota`` map (multi-tenant serving) the byte budget
    is PARTITIONED: each tenant owns a fixed share, an insert can only
    evict that tenant's own entries, and a cross-tenant eviction — the
    isolation violation the partition exists to prevent — is counted in
    ``stats["cross_tenant_evictions"]`` (asserted zero by the invariant
    suite).  ``tenant_quota=None`` (the default) builds none of this
    and is bit-identical to the untenanted store.
    """

    def __init__(self, budget_bytes: int,
                 tenant_quota: Optional[Dict[int, int]] = None):
        self.budget = int(budget_bytes)
        self.entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self.used_bytes = 0
        self.stats = {"inserts": 0, "hits": 0, "misses": 0,
                      "evictions": 0, "premature_evictions": 0,
                      "rejected_inserts": 0, "peak_bytes": 0,
                      "handoffs": 0, "cross_tenant_evictions": 0}
        self.tenant_quota = ({int(t): int(b)
                              for t, b in tenant_quota.items()}
                             if tenant_quota is not None else None)
        self.tenant_used: Optional[Dict[int, int]] = (
            {t: 0 for t in self.tenant_quota}
            if self.tenant_quota is not None else None)
        self.tenant_stats = tenant_ledger(
            self.tenant_quota, "inserts", "hits", "evictions",
            "premature_evictions", "rejected_inserts", "handoffs")

    def __contains__(self, user_id: int) -> bool:
        return user_id in self.entries

    @property
    def live_count(self) -> int:
        return len(self.entries)

    # --- tenant partition helpers (inert when tenant_quota is None) ----------

    def _tenant_budget(self, tenant: int) -> int:
        if self.tenant_quota is None:
            return self.budget
        return self.tenant_quota.get(int(tenant), 0)

    def _taccount(self, tenant: int, delta: int) -> None:
        if self.tenant_used is not None:
            self.tenant_used[int(tenant)] = \
                self.tenant_used.get(int(tenant), 0) + delta

    def _tbump(self, tenant: int, key: str, n: int = 1) -> None:
        if self.tenant_stats is not None:
            self.tenant_stats.setdefault(
                int(tenant),
                {k: 0 for k in next(iter(self.tenant_stats.values()))}
            )[key] += n

    def _victim_uid(self, tenant: int, exclude: Optional[int] = None
                    ) -> Optional[int]:
        """Oldest evictable entry for an insert by ``tenant``: FIFO over
        the whole window when untenanted, FIFO over the tenant's OWN
        entries under a partition (never another tenant's)."""
        for uid, e in self.entries.items():
            if uid == exclude:
                continue
            if self.tenant_quota is not None and e.tenant != tenant:
                continue
            return uid
        return None

    def insert(self, user_id: int, value: Any, nbytes: int, now: float,
               prefix_len: int = 0,
               spans: Optional[Tuple[Tuple[int, int], ...]] = None,
               tenant: int = 0) -> List[CacheEntry]:
        """Insert psi(u); evicts oldest entries past the budget.
        Returns the evicted entries (candidates for DRAM spill).

        An entry larger than the whole budget can never land: it is
        rejected WITHOUT disturbing other entries (evicting everything
        for a doomed insert would only manufacture premature evictions)
        and counted in ``stats["rejected_inserts"]`` so callers observe
        the absence instead of believing psi is resident.  A rejected
        same-user REFRESH still evicts the superseded psi — serving the
        stale cache for the new lifecycle would be the silent-drop bug
        this path exists to prevent.

        Under a tenant partition the budget tests run against the
        tenant's OWN share and the pressure loop only evicts the
        tenant's own entries."""
        if int(nbytes) > self._tenant_budget(tenant):
            evicted = ([self._evict(user_id)]
                       if user_id in self.entries else [])
            self.stats["rejected_inserts"] += 1
            self._tbump(tenant, "rejected_inserts")
            return evicted
        if user_id in self.entries:
            # same-user refresh: the superseded psi leaves the window
            # (counted as an eviction for conservation, never premature —
            # the fresher psi serves this lifecycle)
            self._evict(user_id)
        entry = CacheEntry(user_id, value, int(nbytes), now,
                           prefix_len=prefix_len, tokens_resident=prefix_len,
                           spans=tuple(spans) if spans else None,
                           tenant=int(tenant))
        evicted = []
        used = (self.tenant_used.get(int(tenant), 0)
                if self.tenant_used is not None else self.used_bytes)
        while used + entry.nbytes > self._tenant_budget(tenant) \
                and self.entries:
            old_uid = self._victim_uid(tenant)
            if old_uid is None:
                break
            old = self.entries[old_uid]
            self._evict(old_uid)
            if old.tenant != entry.tenant:
                self.stats["cross_tenant_evictions"] += 1
            if not old.consumed:
                self.stats["premature_evictions"] += 1
                self._tbump(old.tenant, "premature_evictions")
            evicted.append(old)
            used = (self.tenant_used.get(int(tenant), 0)
                    if self.tenant_used is not None else self.used_bytes)
        self.entries[user_id] = entry
        self.used_bytes += entry.nbytes
        self._taccount(tenant, entry.nbytes)
        self.stats["inserts"] += 1
        self._tbump(tenant, "inserts")
        self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                       self.used_bytes)
        return evicted

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is None:
            self.stats["misses"] += 1
        else:
            self.stats["hits"] += 1
            self._tbump(e.tenant, "hits")
        return e

    def consume(self, user_id: int) -> Optional[CacheEntry]:
        """Mark psi(u) consumed by ranking; it stays until evicted by the
        sliding window (it may serve same-lifecycle repeats) but becomes
        the preferred spill candidate."""
        e = self.entries.get(user_id)
        if e is not None:
            e.consumed = True
        return e

    def pop(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is not None:
            self._evict(user_id)
        return e

    def extract(self, user_id: int) -> Optional[CacheEntry]:
        """Remove an entry for ownership HANDOFF during rebalancing —
        not an eviction: the entry continues its lifecycle on another
        instance, so it bypasses the eviction/premature accounting and
        is counted in ``stats["handoffs"]`` instead.  Conservation
        across churn is therefore

            inserts == live_count + evictions + handoffs
        """
        e = self.entries.pop(user_id, None)
        if e is None:
            return None
        self.used_bytes -= e.nbytes
        self._taccount(e.tenant, -e.nbytes)
        self.stats["handoffs"] += 1
        self._tbump(e.tenant, "handoffs")
        return e

    def fits(self, nbytes: int, prefix_len: int = 0,
             tenant: int = 0) -> bool:
        """Could an entry of this size EVER land in the window?  False
        means permanently unpromotable (over the whole budget — or over
        the owning tenant's share, under a partition) — the expander
        uses this to stop scheduling doomed reloads."""
        return int(nbytes) <= self._tenant_budget(tenant)

    def missing_tokens(self, user_id: int, total: int) -> int:
        """Tokens a DRAM->HBM reload must stream for this user.  The
        dense store is all-or-nothing; the paged store subtracts the
        still-resident head pages of a partially evicted entry."""
        return int(total)

    def resident(self, user_id: int) -> Optional[CacheEntry]:
        """Entry if psi is FULLY resident (no hit/miss accounting) —
        the pre-inference dedup probe."""
        return self.entries.get(user_id)

    def touch(self, user_id: int, now: float) -> None:
        """Same-psi refresh without data movement: a deduped pre-infer
        found psi already resident — renew its lifecycle (back of the
        FIFO window, consumption re-armed)."""
        e = self.entries.get(user_id)
        if e is not None:
            e.consumed = False
            e.created_at = now
            self.entries.move_to_end(user_id)

    def acquire_value(self, entry: CacheEntry) -> Any:
        """Snapshot psi for a rank launch.  Paired with
        ``release_value`` after the launch; the paged store pins the
        entry's pages across the (possibly deferred) batched launch so
        window recycling can't free them mid-flight."""
        return entry.value

    def release_value(self, psi: Any) -> None:
        pass

    def _evict(self, user_id: int) -> CacheEntry:
        e = self.entries.pop(user_id)
        self.used_bytes -= e.nbytes
        self._taccount(e.tenant, -e.nbytes)
        e.state = CacheState.EVICTED
        self.stats["evictions"] += 1
        self._tbump(e.tenant, "evictions")
        return e


def _is_kv_pytree(value: Any) -> bool:
    """True for a real per-layer (K, V) psi — (L, B, P, H, D) arrays —
    as opposed to the sim executor's scalar stub."""
    return (isinstance(value, (tuple, list)) and len(value) == 2
            and getattr(value[0], "ndim", 0) == 5
            and getattr(value[1], "ndim", 0) == 5)


class PagedHBMStore(HBMCacheStore):
    """Block-granular HBM window: the ``r1 * HBM`` budget carved into a
    fixed-size page pool (free-list allocator, ``repro.core.paging``).

    Same external contract as the dense store — insert / lookup /
    consume / pop, FIFO window, conserved entry accounting — plus:

      * an entry holds a per-slab *page table* (one row per layer K/V
        plane) instead of a dense pytree; ``used_bytes`` counts whole
        pages, so the only waste is each slab's last-page padding;
      * eviction under pressure can free just the TAIL pages of the
        oldest consumed, DRAM-backed entry (``partial_evictions``) —
        the head stays resident and a later reload *resumes*, streaming
        only the missing pages (``resumed_reloads``);
      * launches pin pages (``acquire_value``/``release_value``), so a
        deferred batched launch never reads a recycled page;
      * in live mode the pool owns a real ``(n_pages + 1, page_tokens,
        H, D)`` buffer (lazily shaped from the first psi; the extra
        last row is the all-zero null page used to pad page tables to a
        bucket) and ``PagedPsi`` handles point into it;
      * with ``device_pool=True`` the pool is a ``DevicePagePool``:
        the host buffer stays the staging area / host-read source, and
        every page write additionally scatters into the device-resident
        mirror (one donated update per insert/resume) so rank launches
        pass the pool by reference instead of re-shipping it.
    """

    def __init__(self, budget_bytes: int, layout: PageLayout,
                 device_pool: bool = False,
                 tenant_quota: Optional[Dict[int, int]] = None):
        super().__init__(budget_bytes, tenant_quota=tenant_quota)
        self.layout = layout
        pool_cls = DevicePagePool if device_pool else PagePool
        self.pool = pool_cls(
            n_pages=int(budget_bytes) // layout.page_bytes,
            page_bytes=layout.page_bytes)
        # page-granular partition: each tenant's byte share floors to
        # whole pages — a tenant's insert can only allocate inside its
        # own page quota, so one tenant's footprint can never starve
        # another's pool (None when untenanted)
        self.tenant_pages: Optional[Dict[int, int]] = (
            {t: int(b) // layout.page_bytes
             for t, b in self.tenant_quota.items()}
            if self.tenant_quota is not None else None)
        self.buffer: Optional[np.ndarray] = None   # lazily shaped
        # device-pool routing: when the runtime wires an executor here
        # (``InstanceRuntime``), page-data movement goes through its
        # insert_pages/free_pages hooks; unwired device pools scatter
        # directly.  None + host pool is the pure-host path.
        self.device_hooks = None
        # gather a dense host copy of psi when it leaves the pool, so
        # the evictee can spill to DRAM; deployments without a DRAM
        # tier turn this off (InstanceRuntime) — the copy would be
        # discarded anyway
        self.materialize_on_evict = True
        self.stats.update({"partial_evictions": 0, "resumed_reloads": 0,
                           "pages_reloaded": 0})

    @property
    def null_page(self) -> int:
        return self.pool.n_pages                   # always-zero pad row

    def _tokens_of(self, nbytes: int, prefix_len: int) -> int:
        if prefix_len > 0:
            return int(prefix_len)
        per_token = self.layout.slabs * self.layout.token_bytes
        return max(1, ceil_div(int(nbytes), per_token))

    def _ensure_buffer(self, value: Any) -> None:
        if self.buffer is not None or not _is_kv_pytree(value):
            return
        k = np.asarray(value[0])
        H, D = k.shape[3], k.shape[4]
        self.buffer = np.zeros(
            (self.pool.n_pages + 1, self.layout.page_tokens, H, D), k.dtype)

    def _land_pages(self, pages) -> None:
        """Route freshly staged pages to the device-resident pool —
        every write path (fresh insert, resumed reload, handoff
        re-insert, cold-promotion landing) converges here, so the
        device mirror can never miss a page a launch may reference."""
        if self.buffer is None:
            return                          # sim mode: no page data
        pages = [int(p) for p in pages]
        if self.device_hooks is not None:
            self.device_hooks.insert_pages(self.pool, pages, self.buffer)
        elif isinstance(self.pool, DevicePagePool):
            self.pool.scatter(pages, self.buffer)

    def _free_pages(self, pages) -> None:
        """Single exit turnstile for page frees (through the executor
        hook when wired, so device- and host-pool deployments free
        through the same conserved accounting)."""
        pages = [int(p) for p in pages]
        if self.device_hooks is not None:
            self.device_hooks.free_pages(self.pool, pages)
        else:
            self.pool.free(pages)

    # --- insert: fresh / refresh / resume -----------------------------------

    def _tenant_page_cap(self, tenant: int) -> int:
        if self.tenant_pages is None:
            return self.pool.n_pages
        return self.tenant_pages.get(int(tenant), 0)

    def _tenant_pages_used(self, tenant: int) -> int:
        if self.tenant_used is None:
            return 0
        return self.tenant_used.get(int(tenant), 0) // self.layout.page_bytes

    def insert(self, user_id: int, value: Any, nbytes: int, now: float,
               prefix_len: int = 0,
               spans: Optional[Tuple[Tuple[int, int], ...]] = None,
               tenant: int = 0) -> List[CacheEntry]:
        tokens = self._tokens_of(nbytes, prefix_len)
        if spans:
            # segmented entry: every span pads to whole pages so spans
            # stay independently addressable; the page math (entry
            # sizing, resume, partial tail eviction) runs on the PADDED
            # total, which becomes the entry's prefix_len.  Live psi
            # for a segmented entry must arrive pre-padded to the same
            # grid (zero pad keys are exact under silu attention).
            pt = self.layout.page_tokens
            tokens = sum(pt * ceil_div(int(ln), pt) for _, ln in spans)
        if _is_kv_pytree(value):
            # live psi arrives on the executor's 64-token prefill grid,
            # which can overhang the page grid — page the WHOLE value
            # so paged and dense ranking see identical keys
            tokens = max(tokens, int(value[0].shape[2]))
        need = self.layout.entry_pages(tokens)
        if need > self._tenant_page_cap(tenant):
            # doomed insert: reject, but never let a superseded psi
            # serve the new lifecycle (same contract as the base store)
            evicted = ([self._evict(user_id)]
                       if user_id in self.entries else [])
            self.stats["rejected_inserts"] += 1
            self._tbump(tenant, "rejected_inserts")
            return evicted
        self._ensure_buffer(value)
        existing = self.entries.get(user_id)
        if (existing is not None and existing.prefix_len == tokens
                and existing.tokens_resident < existing.prefix_len):
            return self._resume(existing, value, now)
        if existing is not None:
            # same-user refresh: superseded psi leaves through the
            # eviction turnstile, exactly like the dense store
            self._evict(user_id)
        evicted = self._make_room(need, exclude=user_id, tenant=tenant)
        pages = self.pool.alloc(need)
        if pages is None:
            # pinned zombie pages of in-flight launches can transiently
            # shrink the pool below the byte budget; reject, observed
            # by the runtime as a miss
            self.stats["rejected_inserts"] += 1
            self._tbump(tenant, "rejected_inserts")
            return evicted
        pps = self.layout.pages_per_slab(tokens)
        table = np.asarray(pages, np.int32).reshape(self.layout.slabs, pps)
        entry = CacheEntry(
            user_id, value, need * self.layout.page_bytes, now,
            prefix_len=tokens, tokens_resident=tokens, page_table=table,
            spans=tuple(spans) if spans else None, tenant=int(tenant))
        if self.buffer is not None and _is_kv_pytree(value):
            slice_into_pages(self.buffer, table, value,
                             self.layout.page_tokens)
            self._land_pages(table.reshape(-1))
            entry.value = PagedPsi(table, tokens, self.layout, self.buffer,
                                   spans=entry.spans, pool=self.pool)
        self.entries[user_id] = entry
        self.used_bytes += entry.nbytes
        self._taccount(tenant, entry.nbytes)
        self.stats["inserts"] += 1
        self._tbump(tenant, "inserts")
        self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                       self.used_bytes)
        return evicted

    def _resume(self, entry: CacheEntry, value: Any, now: float
                ) -> List[CacheEntry]:
        """Partial-reload completion: top up the missing tail pages of a
        partially resident entry instead of restarting from scratch."""
        pps_full = self.layout.pages_per_slab(entry.prefix_len)
        pps_res = self.layout.pages_per_slab(entry.tokens_resident) \
            if entry.tokens_resident else 0
        missing = (pps_full - pps_res) * self.layout.slabs
        evicted = self._make_room(missing, exclude=entry.user_id,
                                  tenant=entry.tenant)
        pages = self.pool.alloc(missing)
        if pages is None:                  # zombie-pinched pool: restart
            evicted.append(self._evict(entry.user_id))
            self.stats["rejected_inserts"] += 1
            return evicted
        fresh = np.asarray(pages, np.int32).reshape(
            self.layout.slabs, pps_full - pps_res)
        table = np.concatenate([entry.page_table[:, :pps_res], fresh],
                               axis=1)
        entry.page_table = table
        if self.buffer is not None and _is_kv_pytree(value):
            t0 = pps_res * self.layout.page_tokens
            slice_into_pages(self.buffer, table, value,
                             self.layout.page_tokens, t0=t0)
            # partial-reload resume: only the missing TAIL pages move
            # over the link — the resident head never re-ships
            self._land_pages(fresh.reshape(-1))
            entry.value = PagedPsi(table, entry.prefix_len, self.layout,
                                   self.buffer, spans=entry.spans,
                                   pool=self.pool)
        added = missing * self.layout.page_bytes
        entry.tokens_resident = entry.prefix_len
        entry.nbytes += added
        entry.consumed = False             # re-armed for this lifecycle
        entry.dram_backed = False          # the DRAM copy moved out
        entry.created_at = now
        self.entries.move_to_end(entry.user_id)
        self.used_bytes += added
        self._taccount(entry.tenant, added)
        self.stats["resumed_reloads"] += 1
        self.stats["pages_reloaded"] += missing
        self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                       self.used_bytes)
        return evicted

    def _make_room(self, need: int, exclude: int, tenant: int = 0
                   ) -> List[CacheEntry]:
        """Free pages until ``need`` fit: partial tail eviction of the
        oldest consumed DRAM-backed entry when that covers the deficit,
        whole-entry FIFO eviction otherwise.  Under a tenant partition
        the pressure test is the tenant's own page quota and victims
        come only from the tenant's own entries."""
        evicted: List[CacheEntry] = []
        while (self.pool.free_pages < need
               or self._tenant_pages_used(tenant) + need
               > self._tenant_page_cap(tenant)):
            victim = self._victim_uid(tenant, exclude=exclude)
            if victim is None:
                break
            old = self.entries[victim]
            if self.tenant_pages is None:
                deficit = need - self.pool.free_pages
            else:
                deficit = (self._tenant_pages_used(tenant) + need
                           - self._tenant_page_cap(tenant))
            per_slab = ceil_div(deficit, self.layout.slabs)
            pps_res = self.layout.pages_per_slab(old.tokens_resident) \
                if old.tokens_resident else 0
            if (old.consumed and old.dram_backed and 0 < per_slab < pps_res):
                # free just the tail pages; the head stays resident and
                # the next reload for this user resumes from it
                keep = pps_res - per_slab
                tail = old.page_table[:, keep:pps_res].reshape(-1)
                self._free_pages(tail)
                freed = per_slab * self.layout.slabs
                old.tokens_resident = keep * self.layout.page_tokens
                old.nbytes -= freed * self.layout.page_bytes
                self.used_bytes -= freed * self.layout.page_bytes
                self._taccount(old.tenant, -freed * self.layout.page_bytes)
                self.stats["partial_evictions"] += 1
                continue
            self._evict(victim)
            if old.tenant != int(tenant):
                self.stats["cross_tenant_evictions"] += 1
            if not old.consumed:
                self.stats["premature_evictions"] += 1
                self._tbump(old.tenant, "premature_evictions")
            evicted.append(old)
        return evicted

    # --- residency-aware lookups --------------------------------------------

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is not None and e.tokens_resident < e.prefix_len:
            self.stats["misses"] += 1      # partial: ranking needs all of psi
            return None
        return super().lookup(user_id)

    def fits(self, nbytes: int, prefix_len: int = 0,
             tenant: int = 0) -> bool:
        tokens = self._tokens_of(nbytes, prefix_len)
        return self.layout.entry_pages(tokens) \
            <= self._tenant_page_cap(tenant)

    def missing_tokens(self, user_id: int, total: int) -> int:
        e = self.entries.get(user_id)
        if e is None or e.prefix_len != int(total):
            return int(total)
        return max(int(total) - e.tokens_resident, 0)

    def resident(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is None or e.tokens_resident < e.prefix_len:
            return None
        return e

    def extract(self, user_id: int) -> Optional[CacheEntry]:
        """Handoff removal, page-pool flavour: the travelling copy must
        be detached from this pool, so a fully resident PagedPsi is
        materialized to a dense host pytree before its pages are freed.
        A partially resident entry's stale head is worthless off-host —
        its full DRAM backing copy (it is dram_backed by construction)
        migrates instead, and the value travels as ``None``."""
        e = self.entries.get(user_id)
        if e is None:
            return None
        if e.page_table is not None:
            pps_res = self.layout.pages_per_slab(e.tokens_resident) \
                if e.tokens_resident else 0
            if isinstance(e.value, PagedPsi):
                full = e.tokens_resident >= e.prefix_len
                e.value = e.value.materialize() if full else None
            self._free_pages(e.page_table[:, :pps_res].reshape(-1))
            e.page_table = None
        return super().extract(user_id)

    # --- launch pinning ------------------------------------------------------

    def acquire_value(self, entry: CacheEntry) -> Any:
        if entry.page_table is None:
            return entry.value
        pps = self.layout.pages_per_slab(entry.tokens_resident)
        psi = PagedPsi(entry.page_table[:, :pps].copy(),
                       entry.tokens_resident, self.layout, self.buffer,
                       spans=entry.spans, pool=self.pool)
        self.pool.pin(psi.pages)
        return psi

    def release_value(self, psi: Any) -> None:
        if isinstance(psi, PagedPsi):
            self.pool.unpin(psi.pages)

    # --- eviction frees pages ------------------------------------------------

    def _evict(self, user_id: int) -> CacheEntry:
        e = self.entries[user_id]
        if e.page_table is not None:
            pps_res = self.layout.pages_per_slab(e.tokens_resident) \
                if e.tokens_resident else 0
            if isinstance(e.value, PagedPsi):
                # psi leaves the pool: materialize the dense copy for a
                # possible DRAM spill BEFORE the pages are recycled.
                # Skipped when the copy could never be used — no DRAM
                # tier, unconsumed victim (never spilled), or an entry
                # whose byte-identical DRAM copy already exists (the
                # consume-time spill or a partial entry's backing;
                # value None makes the expander keep the existing copy)
                spillable = (self.materialize_on_evict and e.consumed
                             and not e.dram_backed
                             and e.tokens_resident >= e.prefix_len)
                e.value = e.value.materialize() if spillable else None
            self._free_pages(e.page_table[:, :pps_res].reshape(-1))
            e.page_table = None
            e.tokens_resident = 0
        return super()._evict(user_id)


def make_hbm_store(budget_bytes: int, layout: Optional[PageLayout] = None,
                   device_pool: bool = False,
                   tenant_quota: Optional[Dict[int, int]] = None
                   ) -> HBMCacheStore:
    """Window factory: dense store, or the paged pool when a layout is
    given (``ClusterConfig.page_tokens > 0``).  ``device_pool`` makes
    the pool's data plane a device-resident array mutated in place by
    scatter-on-insert (``ClusterConfig.device_pool``).  ``tenant_quota``
    (tenant id -> byte share) partitions the window per tenant."""
    if layout is None:
        return HBMCacheStore(budget_bytes, tenant_quota=tenant_quota)
    return PagedHBMStore(budget_bytes, layout, device_pool=device_pool,
                         tenant_quota=tenant_quota)
