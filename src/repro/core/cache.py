"""HBM-resident prefix-cache store — the sliding lifecycle window.

Admitted prefix caches psi(u) are inserted by pre-inference, consumed by
ranking within the request lifecycle T_life, and evicted as new admitted
users arrive (paper Fig. 10).  The store enforces the byte budget
``r1 * HBM`` from invariant I2; admission control (trigger) is what makes
the budget sufficient for survival — the store itself just implements
the window and reports violations (an admitted-but-evicted-before-
consumption cache counts as a ``premature_eviction``; under a correctly
configured trigger this stays at zero, and the property tests assert it).

Accounting is conserved: every entry that ever entered the window is
either still live or counted in ``evictions`` (budget pressure,
same-user refresh, or an explicit ``pop``), so

    stats["inserts"] == live_count + stats["evictions"]

holds after any interleaving (tests/test_cache_properties.py).

In live mode ``CacheEntry.value`` holds the real per-layer KV pytree
psi(u) — (K, V) arrays of shape (L, B, P, H, D) as produced by
``HSTUModel.prefill`` — which the batched executor pads and stacks
directly (``repro.serving.batching.pad_psi``); ``kv_nbytes`` sizes such
a pytree for budget accounting.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .types import CacheState


def kv_nbytes(value: Any) -> int:
    """Bytes held by a KV pytree (nested tuples/lists/dicts of arrays);
    scalar/stub values (the sim executor's psi token) count as zero."""
    if isinstance(value, (tuple, list)):
        return sum(kv_nbytes(v) for v in value)
    if isinstance(value, dict):
        return sum(kv_nbytes(v) for v in value.values())
    shape = getattr(value, "shape", None)
    dtype = getattr(value, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape)) * np.dtype(dtype).itemsize


@dataclasses.dataclass
class CacheEntry:
    user_id: int
    value: Any                 # pytree of per-layer KV (or a byte-size stub)
    nbytes: int
    created_at: float
    state: CacheState = CacheState.HBM
    consumed: bool = False
    prefix_len: int = 0


class HBMCacheStore:
    """FIFO sliding-window cache under a byte budget (single instance)."""

    def __init__(self, budget_bytes: int):
        self.budget = int(budget_bytes)
        self.entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self.used_bytes = 0
        self.stats = {"inserts": 0, "hits": 0, "misses": 0,
                      "evictions": 0, "premature_evictions": 0,
                      "peak_bytes": 0}

    def __contains__(self, user_id: int) -> bool:
        return user_id in self.entries

    @property
    def live_count(self) -> int:
        return len(self.entries)

    def insert(self, user_id: int, value: Any, nbytes: int, now: float,
               prefix_len: int = 0) -> List[CacheEntry]:
        """Insert psi(u); evicts oldest entries past the budget.
        Returns the evicted entries (candidates for DRAM spill)."""
        if user_id in self.entries:
            # same-user refresh: the superseded psi leaves the window
            # (counted as an eviction for conservation, never premature —
            # the fresher psi serves this lifecycle)
            self._evict(user_id)
        entry = CacheEntry(user_id, value, int(nbytes), now,
                           prefix_len=prefix_len)
        evicted = []
        while self.used_bytes + entry.nbytes > self.budget and self.entries:
            old_uid, old = next(iter(self.entries.items()))
            self._evict(old_uid)
            if not old.consumed:
                self.stats["premature_evictions"] += 1
            evicted.append(old)
        if entry.nbytes <= self.budget:
            self.entries[user_id] = entry
            self.used_bytes += entry.nbytes
            self.stats["inserts"] += 1
            self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                           self.used_bytes)
        return evicted

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is None:
            self.stats["misses"] += 1
        else:
            self.stats["hits"] += 1
        return e

    def consume(self, user_id: int) -> Optional[CacheEntry]:
        """Mark psi(u) consumed by ranking; it stays until evicted by the
        sliding window (it may serve same-lifecycle repeats) but becomes
        the preferred spill candidate."""
        e = self.entries.get(user_id)
        if e is not None:
            e.consumed = True
        return e

    def pop(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is not None:
            self._evict(user_id)
        return e

    def _evict(self, user_id: int) -> CacheEntry:
        e = self.entries.pop(user_id)
        self.used_bytes -= e.nbytes
        e.state = CacheState.EVICTED
        self.stats["evictions"] += 1
        return e
