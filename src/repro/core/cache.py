"""HBM-resident prefix-cache store — the sliding lifecycle window.

Admitted prefix caches psi(u) are inserted by pre-inference, consumed by
ranking within the request lifecycle T_life, and evicted as new admitted
users arrive (paper Fig. 10).  The store enforces the byte budget
``r1 * HBM`` from invariant I2; admission control (trigger) is what makes
the budget sufficient for survival — the store itself just implements
the window and reports violations (an admitted-but-evicted-before-
consumption cache counts as a ``premature_eviction``; under a correctly
configured trigger this stays at zero, and the property tests assert it).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from .types import CacheState


@dataclasses.dataclass
class CacheEntry:
    user_id: int
    value: Any                 # pytree of per-layer KV (or a byte-size stub)
    nbytes: int
    created_at: float
    state: CacheState = CacheState.HBM
    consumed: bool = False
    prefix_len: int = 0


class HBMCacheStore:
    """FIFO sliding-window cache under a byte budget (single instance)."""

    def __init__(self, budget_bytes: int):
        self.budget = int(budget_bytes)
        self.entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self.used_bytes = 0
        self.stats = {"inserts": 0, "hits": 0, "misses": 0,
                      "evictions": 0, "premature_evictions": 0,
                      "peak_bytes": 0}

    def __contains__(self, user_id: int) -> bool:
        return user_id in self.entries

    @property
    def live_count(self) -> int:
        return len(self.entries)

    def insert(self, user_id: int, value: Any, nbytes: int, now: float,
               prefix_len: int = 0) -> List[CacheEntry]:
        """Insert psi(u); evicts oldest entries past the budget.
        Returns the evicted entries (candidates for DRAM spill)."""
        if user_id in self.entries:
            self._remove(user_id)
        entry = CacheEntry(user_id, value, int(nbytes), now,
                           prefix_len=prefix_len)
        evicted = []
        while self.used_bytes + entry.nbytes > self.budget and self.entries:
            old_uid, old = next(iter(self.entries.items()))
            self._remove(old_uid)
            old.state = CacheState.EVICTED
            self.stats["evictions"] += 1
            if not old.consumed:
                self.stats["premature_evictions"] += 1
            evicted.append(old)
        if entry.nbytes <= self.budget:
            self.entries[user_id] = entry
            self.used_bytes += entry.nbytes
            self.stats["inserts"] += 1
            self.stats["peak_bytes"] = max(self.stats["peak_bytes"],
                                           self.used_bytes)
        return evicted

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is None:
            self.stats["misses"] += 1
        else:
            self.stats["hits"] += 1
        return e

    def consume(self, user_id: int) -> Optional[CacheEntry]:
        """Mark psi(u) consumed by ranking; it stays until evicted by the
        sliding window (it may serve same-lifecycle repeats) but becomes
        the preferred spill candidate."""
        e = self.entries.get(user_id)
        if e is not None:
            e.consumed = True
        return e

    def pop(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is not None:
            self._remove(user_id)
        return e

    def _remove(self, user_id: int):
        e = self.entries.pop(user_id)
        self.used_bytes -= e.nbytes
