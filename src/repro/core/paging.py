"""Block-granular psi storage: the fixed-size HBM page pool.

The unpaged window stores each admitted psi(u) as one monolithic pytree,
so mixed prefix lengths fragment the ``r1 * HBM`` budget (invariant I2)
and every spill/reload moves a whole prefix.  Paging fixes both: the
budget is carved into fixed-size pages of ``page_tokens`` tokens each,
an entry owns a *page table* instead of a dense buffer, and the only
waste is the zero padding of each slab's last page.

Layout.  psi(u) is the per-layer (K, V) pytree of shape
``(L, B, P, H, D)``; paging slices the token axis P.  Each of the
``2 * L`` K/V planes — called *slabs* here — is paged independently, so
one page holds ``page_tokens`` tokens of ONE slab, shaped
``(page_tokens, H, D)``.  A ``PagedPsi`` handle carries the
``(slabs, n_pages)`` page table; the paged Pallas kernel
(``repro.kernels.paged_prefix_attn``) and the live executor's
``rank_with_pages`` path gather K/V directly from the pool through it.

Accounting is conserved at page granularity, mirroring the entry-level
turnstile of the HBM window:

    stats["pages_allocated"] == pages_live + stats["pages_freed"]

after any interleaving, and the free list never double-allocates
(tests/test_cache_properties.py).  Pages referenced by an in-flight
rank launch are *pinned*: freeing a pinned page parks it in a zombie
set (still occupying the pool, still "live") and the release after the
launch returns it to the free list — so a batched group can never read
a page the window recycled under it.

``DevicePagePool`` keeps the same bookkeeping but makes the data plane
a device-resident jax array mutated in place: freshly written pages
scatter in via a donated ``.at[pages].set(...)`` update and rank
launches pass the pool by reference (zero per-launch re-ship); the
``h2d`` ledger on every pool accounts the host->device traffic either
way.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Static geometry of the page pool for one model family."""
    page_tokens: int
    slabs: int                  # independently paged K/V planes: 2 * L
    token_bytes: int            # bytes per token per slab: H * D * itemsize

    @property
    def page_bytes(self) -> int:
        return self.page_tokens * self.token_bytes

    def pages_per_slab(self, tokens: int) -> int:
        return ceil_div(max(int(tokens), 1), self.page_tokens)

    def entry_pages(self, tokens: int) -> int:
        """Pool pages held by a fully resident psi of ``tokens`` tokens."""
        return self.slabs * self.pages_per_slab(tokens)

    def entry_bytes(self, tokens: int) -> int:
        return self.entry_pages(tokens) * self.page_bytes

    @classmethod
    def from_model_config(cls, cfg, page_tokens: int) -> "PageLayout":
        # pages must tile the 64-token shape-bucket grid exactly, or the
        # paged launch pads to a different context length than the dense
        # bucketed path and the 1/n_total normalizer silently diverges —
        # fail at config time instead of producing wrong scores
        if page_tokens <= 0 or 64 % int(page_tokens) != 0:
            raise ValueError(
                f"page_tokens={page_tokens} must divide the 64-token "
                f"bucket grid (1, 2, 4, 8, 16, 32 or 64) so paged and "
                f"dense launches share shape buckets and normalizers")
        itemsize = 4 if cfg.dtype == "float32" else 2
        return cls(page_tokens=int(page_tokens),
                   slabs=2 * cfg.n_layers,
                   token_bytes=cfg.n_heads * cfg.head_dim * itemsize)


class PagePool:
    """Free-list allocator over a fixed number of pages.

    Pure bookkeeping — data lives in the owner's (optional) page buffer,
    indexed by the ids handed out here.  Conservation invariant:
    ``stats["pages_allocated"] == pages_live + stats["pages_freed"]``
    where a page stays *live* from alloc until it actually returns to
    the free list (a freed-but-pinned zombie is still live: it occupies
    pool capacity until the pinning launch releases it).
    """

    def __init__(self, n_pages: int, page_bytes: int):
        self.n_pages = int(n_pages)
        self.page_bytes = int(page_bytes)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._pins: Dict[int, int] = {}     # page id -> in-flight refs
        self._zombies: set = set()          # freed while pinned
        self.stats = {"pages_allocated": 0, "pages_freed": 0,
                      "alloc_failures": 0, "peak_pages": 0}
        # host->device traffic ledger.  On a DevicePagePool the scatter
        # side counts every page landed in the device-resident buffer
        # (``bytes_scattered`` == bytes of freshly written pages) and
        # ``launch_reships`` stays 0; on a host-buffer pool the launch
        # path counts each whole-pool re-ship instead.
        self.h2d = {"bytes_scattered": 0, "pages_scattered": 0,
                    "scatters": 0, "launch_reships": 0,
                    "reshipped_bytes": 0}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def zombie_pages(self) -> int:
        return len(self._zombies)

    @property
    def pages_live(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` page ids, or None (and a counted failure) if the
        free list is short — the caller evicts and retries."""
        if n > len(self._free):
            self.stats["alloc_failures"] += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.stats["pages_allocated"] += n
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.pages_live)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if self._pins.get(p, 0) > 0:
                self._zombies.add(p)        # still live until unpinned
            else:
                self._free.append(p)
                self.stats["pages_freed"] += 1

    def pin(self, pages: Sequence[int]) -> None:
        for p in pages:
            self._pins[p] = self._pins.get(p, 0) + 1

    def unpin(self, pages: Sequence[int]) -> None:
        for p in pages:
            n = self._pins.get(p, 0) - 1
            if n <= 0:
                self._pins.pop(p, None)
                if p in self._zombies:      # deferred free fires now
                    self._zombies.discard(p)
                    self._free.append(p)
                    self.stats["pages_freed"] += 1
            else:
                self._pins[p] = n


_SCATTER_JIT = None


def _scatter_jit():
    """Jitted donated page scatter, shared by every DevicePagePool so
    the compile cache is per-(pool shape, batch grid), not per-pool.
    Donating the pool argument lets XLA update the buffer in place —
    the pool is never copied on insert."""
    global _SCATTER_JIT
    if _SCATTER_JIT is None:
        import jax
        _SCATTER_JIT = jax.jit(lambda buf, idx, vals: buf.at[idx].set(vals),
                               donate_argnums=(0,))
    return _SCATTER_JIT


class DevicePagePool(PagePool):
    """Page pool whose data plane is a device-resident array mutated in
    place: inserts and reload completions ``scatter`` only the freshly
    written pages into the resident buffer via a donated
    ``.at[pages].set(...)`` update, and rank launches pass the buffer by
    reference — zero per-launch host->device re-ship.

    Bookkeeping (free list, pins, zombies, conservation) is inherited
    unchanged, so stale-page reuse is impossible by construction: a
    freed page cannot re-enter a table until the allocator hands it out
    again, and every allocation is rewritten (host slice + scatter)
    before any launch can reference it — the stale device bytes of a
    recycled page are unreadable in between.  The owner's host buffer
    stays the staging area and source of truth for host-side reads
    (``PagedPsi.materialize`` on evict-spill / handoff-extract); the
    device buffer mirrors it incrementally, starting from device-side
    zeros so ``h2d["bytes_scattered"]`` counts exactly the inserted
    page bytes."""

    def __init__(self, n_pages: int, page_bytes: int):
        super().__init__(n_pages, page_bytes)
        self.device_buffer = None           # lazily shaped, jax array

    def ensure_device(self, host_buffer: np.ndarray):
        """Create the resident buffer on first use — device-side zeros
        (matching the zero-filled host pool), so creation itself moves
        no bytes over the link."""
        if self.device_buffer is None:
            import jax.numpy as jnp
            self.device_buffer = jnp.zeros(host_buffer.shape,
                                           host_buffer.dtype)
        return self.device_buffer

    def device_view(self, host_buffer: np.ndarray):
        """The resident pool buffer a launch passes by reference."""
        return self.ensure_device(host_buffer)

    def scatter(self, pages: Sequence[int], host_buffer: np.ndarray) -> int:
        """Land freshly written ``pages`` (already sliced into
        ``host_buffer``) in the device-resident pool.  The page-id axis
        pads to a power-of-two grid by repeating the first page (same
        index, same value — set() is idempotent), bounding the jit
        cache to log2(n_pages) entries.  Returns the logical bytes
        moved (padding repeats a page already being sent; only the
        logical traffic is accounted)."""
        pages = [int(p) for p in pages]
        if not pages:
            return 0
        import jax.numpy as jnp
        self.ensure_device(host_buffer)
        grid = 1
        while grid < len(pages):
            grid *= 2
        idx = np.asarray(pages + [pages[0]] * (grid - len(pages)), np.int32)
        self.device_buffer = _scatter_jit()(
            self.device_buffer, jnp.asarray(idx),
            jnp.asarray(host_buffer[idx]))
        nbytes = len(pages) * self.page_bytes
        self.h2d["bytes_scattered"] += nbytes
        self.h2d["pages_scattered"] += len(pages)
        self.h2d["scatters"] += 1
        return nbytes


class PagedPsi:
    """Handle to a paged psi: the page table plus the pool buffer.

    This is what a paged ``CacheEntry.value`` holds in live mode and
    what ``classify_rank`` snapshots for a (possibly deferred) batched
    launch.  ``table`` is ``(slabs, n_pages)`` int32 — row ``2*l`` is
    layer ``l``'s K plane, row ``2*l + 1`` its V plane.  ``materialize``
    gathers back to the dense ``(L, 1, P, H, D)`` (K, V) pytree — used
    when psi leaves the pool (DRAM spill) — with P padded to the page
    grid (zero tail, exact for HSTU's silu attention).
    """

    def __init__(self, table: np.ndarray, n_tokens: int, layout: PageLayout,
                 buffer: Optional[np.ndarray], spans=None,
                 pool: Optional[PagePool] = None):
        self.table = np.asarray(table, np.int32)
        self.n_tokens = int(n_tokens)
        self.layout = layout
        self.buffer = buffer
        # owning pool (when handed out by a PagedHBMStore): lets the
        # launch path pass a DevicePagePool's resident buffer by
        # reference instead of re-shipping the host pool per launch
        self.pool = pool
        # beyond-prefix reuse: ordered (global_start, valid_len) cached
        # spans; None for prefix-only psi.  Each span occupies whole
        # pages (``n_tokens`` is the padded total), so the consumer can
        # derive the kernel's page_pos/page_valid tables from it.
        self.spans = tuple(spans) if spans else None

    @property
    def pages(self) -> List[int]:
        return [int(p) for p in self.table.reshape(-1)]

    def materialize(self) -> Any:
        assert self.buffer is not None, "sim-mode psi has no page data"
        slabs, np_ = self.table.shape
        L = slabs // 2
        # (slabs, n_pages, pt, H, D) -> (slabs, P_padded, H, D)
        flat = self.buffer[self.table].reshape(
            slabs, np_ * self.layout.page_tokens, *self.buffer.shape[2:])
        k = flat[0::2][:, None]             # (L, 1, P, H, D)
        v = flat[1::2][:, None]
        return (k.copy(), v.copy())


def slice_into_pages(buffer: np.ndarray, table: np.ndarray, value: Any,
                     page_tokens: int, t0: int = 0) -> None:
    """Write the dense psi pytree ``value`` — per-layer (K, V) arrays of
    shape (L, B, P, H, D) — into pool ``buffer`` pages named by
    ``table`` (slabs, n_pages), starting at token ``t0`` (page-aligned;
    nonzero for partial-reload resume).  The tail of the last page is
    zeroed so padded tokens contribute silu(0) = 0 exactly."""
    k, v = value
    k, v = np.asarray(k), np.asarray(v)
    P = k.shape[2]
    assert t0 % page_tokens == 0, (t0, page_tokens)
    for slab in range(table.shape[0]):
        src = (k if slab % 2 == 0 else v)[slab // 2, 0]   # (P, H, D)
        for j in range(t0 // page_tokens, table.shape[1]):
            pid = int(table[slab, j])
            lo = j * page_tokens
            hi = min(lo + page_tokens, P)
            n = max(hi - lo, 0)
            if n > 0:
                buffer[pid, :n] = src[lo:hi]
            buffer[pid, n:] = 0.0
