"""Request/lifecycle types shared by the RelayGR core.

A recommendation request flows retrieval -> pre-processing -> fine-grained
ranking.  RelayGR adds a *relay-race* side path: an auxiliary, response-
free pre-infer signal issued during retrieval.  Both the signal and the
eventual ranking request carry the user-keyed ``consistency-hash-key``
header so the affinity router lands them on the same special instance.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional, Tuple

HASH_KEY = "consistency-hash-key"


class Stage(str, enum.Enum):
    PRE_INFER = "pre-infer"
    RANK = "rank"


class CacheState(str, enum.Enum):
    PENDING = "pending"        # pre-infer admitted, compute in flight
    HBM = "hbm"                # resident in device memory (live window)
    DRAM = "dram"              # spilled to server-local DRAM
    COLD = "cold"              # demoted to host SSD / remote psi store
    EVICTED = "evicted"


class HitKind(str, enum.Enum):
    HBM_HIT = "hbm_hit"
    DRAM_HIT = "dram_hit"      # required a DRAM->HBM reload
    COLD_HIT = "cold_hit"      # revived from the cold tier this lifecycle
    MISS_FALLBACK = "miss"     # full inference on the critical path


@dataclasses.dataclass
class UserMeta:
    """Lightweight behaviour metadata the trigger inspects during
    retrieval (it never touches the full behaviour sequence)."""
    user_id: int
    prefix_len: int            # long-term behaviour tokens
    incr_len: int = 64         # short-term behaviours + cross features
    dim: int = 256             # feature/embedding dimension
    n_items: int = 512         # candidate items reaching ranking
    # beyond-prefix reuse (RcLLM): lengths of candidate-independent
    # interior segments WITHIN the incr region — behaviour runs whose
    # psi does not depend on the candidate items, so the side path can
    # compute and cache them alongside the prefix.  Empty = prefix-only
    # (the default; every non-segment workload leaves this untouched).
    # sum(seg_lens) <= incr_len; the remainder is fresh critical-path
    # tokens.
    seg_lens: Tuple[int, ...] = ()
    # multi-tenant serving: the scenario/surface this request belongs
    # to.  Tenant 0 is the default — single-tenant deployments never
    # set it and every tenant-aware code path is inert for them.
    tenant: int = 0


def reuse_spans(meta: "UserMeta"
                ) -> Optional[Tuple[Tuple[int, int], ...]]:
    """Deterministic (global_start, length) layout of a user's reusable
    spans: the prefix plus the candidate-independent interior segments,
    the latter interleaved with the fresh incr tokens (an equal fresh
    gap precedes each segment; the remainder — including the items —
    trails the last one).  Returns None for prefix-only users, so every
    non-segment path is untouched."""
    segs = tuple(int(s) for s in (meta.seg_lens or ()))
    if not segs:
        return None
    spans = []
    if meta.prefix_len:
        spans.append((0, int(meta.prefix_len)))
    fresh = max(int(meta.incr_len) - sum(segs), 0)
    gap = fresh // (len(segs) + 1)
    cursor = int(meta.prefix_len)
    for ln in segs:
        cursor += gap
        spans.append((cursor, ln))
        cursor += ln
    return tuple(spans)


@dataclasses.dataclass
class Request:
    req_id: int
    user: UserMeta
    stage: Stage
    t_arrival: float = 0.0
    header: Dict[str, Any] = dataclasses.field(default_factory=dict)
    body: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def pre_infer(cls, req_id: int, user: UserMeta, now: float = 0.0):
        """The auxiliary response-free pre-infer signal (paper §3.2)."""
        return cls(
            req_id=req_id, user=user, stage=Stage.PRE_INFER, t_arrival=now,
            header={HASH_KEY: user.user_id},
            body={"user_id": user.user_id, "stage": Stage.PRE_INFER.value},
        )

    @classmethod
    def rank(cls, req_id: int, user: UserMeta, items=None, now: float = 0.0,
             long_sequence: bool = True):
        header = {HASH_KEY: user.user_id} if long_sequence else {}
        return cls(
            req_id=req_id, user=user, stage=Stage.RANK, t_arrival=now,
            header=header,
            body={"user_id": user.user_id, "items": items},
        )


@dataclasses.dataclass
class RankResult:
    req_id: int
    user_id: int
    hit: HitKind
    scores: Any = None
    latency_ms: float = 0.0
    components: Dict[str, float] = dataclasses.field(default_factory=dict)
    instance: str = ""
