"""Memory-aware expander (paper §3.4): server-local DRAM reuse tier.

HBM bridges a single request lifecycle; DRAM extends reuse across
repeated requests from the same user (rapid refresh) at bounded H2D
cost.  Three mechanisms:

  * two-level lookup: HBM first, DRAM on miss, then DRAM->HBM reload;
  * per-user single-flight: at most one cache-affecting action in flight
    per user — concurrent requests wait and then hit HBM;
  * pseudo-pre-infer: a lightweight cache-check step enqueued in front of
    every ranking request, so out-of-order arrivals (ranking before the
    real pre-infer lands) trigger at most ONE reload per user per burst.

Reloads are additionally rate-limited with a bounded-concurrency gate so
the expander cannot become a new PCIe bottleneck.

With a paged HBM window (``repro.core.cache.PagedHBMStore``) both
directions go block-granular: a spill materializes psi out of the page
pool into a dense host copy, and a reload streams only the pages the
window is missing — a partially evicted entry (tail pages freed under
pressure) RESUMES from its resident head instead of restarting, with
``CacheEntry.reload_tokens`` carrying the remaining transfer so the
executor prices exactly the missing pages.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from .cache import CacheEntry, HBMCacheStore, tenant_ledger
from .paging import PagedPsi
from .types import CacheState


@dataclasses.dataclass
class ExpanderConfig:
    dram_budget_bytes: float = 500e9
    max_reload_concurrency: int = 4


class SingleFlight:
    """Per-user in-flight op registry. begin() returns True for the op
    leader; followers queue and are released on end()."""

    def __init__(self):
        self._inflight: Dict[int, int] = {}

    def begin(self, user_id: int) -> bool:
        n = self._inflight.get(user_id, 0)
        self._inflight[user_id] = n + 1
        return n == 0

    def end(self, user_id: int):
        n = self._inflight.get(user_id, 0)
        if n <= 1:
            self._inflight.pop(user_id, None)
        else:
            self._inflight[user_id] = n - 1

    def active(self, user_id: int) -> bool:
        """True while any op for this user is still in flight."""
        return self._inflight.get(user_id, 0) > 0

    def waiters(self, user_id: int) -> int:
        return max(0, self._inflight.get(user_id, 0) - 1)


class DRAMExpander:
    def __init__(self, cfg: ExpanderConfig,
                 tenant_quota: Optional[Dict[int, int]] = None):
        self.cfg = cfg
        self.entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self.used_bytes = 0
        self.flight = SingleFlight()
        self.active_reloads = 0
        # multi-tenant partition: tenant id -> byte share of the DRAM
        # budget.  A tenant's spill only LRU-evicts that tenant's own
        # copies; None (single-tenant) builds no tenant machinery.
        self.tenant_quota = ({int(t): int(b)
                              for t, b in tenant_quota.items()}
                             if tenant_quota is not None else None)
        self.tenant_used: Optional[Dict[int, int]] = (
            {t: 0 for t in self.tenant_quota}
            if self.tenant_quota is not None else None)
        self.tenant_stats = tenant_ledger(
            self.tenant_quota, "inserts", "evictions", "demotions",
            "promotions", "handoffs", "spills", "dram_hits",
            "lru_evictions")
        # Optional cold-tier hook: when a runtime wires a sink, LRU
        # evictees are DEMOTED down the hierarchy (the sink prices and
        # lands the copy asynchronously) instead of dropped.  Returns
        # whether the sink accepted the entry.
        self.demote_sink = None
        # Unified tier counter family (same core as HBMCacheStore and
        # ColdStore, so stats() renders one coherent table):
        #   inserts == live + evictions + demotions + handoffs + promotions
        # evictions  — copies dropped from the hierarchy (LRU without a
        #              cold tier, same-user replacement, unfit drops);
        # demotions  — LRU evictees accepted by the cold-tier sink;
        # promotions — copies moved UP (DRAM -> HBM reload completed);
        # handoffs   — extracted for rebalance migration.
        # The rest are tier-specific extras (note lru_evictions counts
        # ALL LRU removals, demoted or dropped).
        self.stats = {"inserts": 0, "evictions": 0, "demotions": 0,
                      "promotions": 0, "handoffs": 0,
                      "spills": 0, "reloads": 0, "redundant_avoided": 0,
                      "dram_hits": 0, "dram_misses": 0, "lru_evictions": 0,
                      "reload_throttled": 0, "unfit_dropped": 0,
                      "rejected_spills": 0, "cross_tenant_evictions": 0}

    # --- tenant partition helpers ------------------------------------------
    def _tenant_budget(self, tenant: int) -> float:
        if self.tenant_quota is None:
            return self.cfg.dram_budget_bytes
        return self.tenant_quota.get(int(tenant), 0)

    def _taccount(self, tenant: int, delta: int):
        if self.tenant_used is not None:
            t = int(tenant)
            self.tenant_used[t] = self.tenant_used.get(t, 0) + delta

    def _tbump(self, tenant: int, key: str, n: int = 1):
        if self.tenant_stats is not None:
            s = self.tenant_stats.get(int(tenant))
            if s is not None:
                s[key] = s.get(key, 0) + n

    def _lru_victim(self, tenant: int) -> Optional[int]:
        """Oldest entry eligible for eviction on behalf of ``tenant``:
        the global LRU head untenanted, the tenant's OWN LRU head under
        partition (a tenant's spill never displaces another tenant)."""
        for uid, e in self.entries.items():
            if self.tenant_quota is not None and e.tenant != int(tenant):
                continue
            return uid
        return None

    # --- spill (after consumption, off the critical path) -------------------
    def spill(self, entry: CacheEntry) -> bool:
        """Store ``entry`` in the DRAM tier; returns whether it fit
        (callers use this for their own spill accounting)."""
        if entry.value is None:
            # a partially evicted paged entry finally left the window:
            # its stale head is worthless, but the full DRAM copy made
            # at consume time already lives here — keep it fresh
            if entry.user_id in self.entries:
                self.entries.move_to_end(entry.user_id)
                return True
            return False
        if entry.nbytes > self._tenant_budget(entry.tenant):
            # an entry that can never fit must be rejected UP FRONT,
            # without disturbing the tier: letting it reach the LRU
            # loop would evict every resident psi before the final fit
            # check rejects it anyway (mirror of the HBM window's
            # rejected_inserts)
            self.stats["rejected_spills"] += 1
            return False
        if isinstance(entry.value, PagedPsi):
            # psi leaves the pool: the DRAM copy is a dense host pytree,
            # detached from page ids the window is free to recycle
            entry = dataclasses.replace(
                entry, value=entry.value.materialize(), page_table=None,
                tokens_resident=entry.prefix_len)
        elif entry.page_table is not None:
            entry = dataclasses.replace(entry, page_table=None,
                                        tokens_resident=entry.prefix_len)
        if entry.user_id in self.entries:
            stale = self._remove(entry.user_id)
            self.stats["evictions"] += 1       # replaced same-user copy
            self._tbump(stale.tenant, "evictions")
        used = (self.tenant_used.get(int(entry.tenant), 0)
                if self.tenant_used is not None else self.used_bytes)
        while (used + entry.nbytes > self._tenant_budget(entry.tenant)
               and self.entries):
            old_uid = self._lru_victim(entry.tenant)
            if old_uid is None:
                break
            _ = self._remove(old_uid)          # LRU (same-tenant under quota)
            if _.tenant != entry.tenant:
                self.stats["cross_tenant_evictions"] += 1
            self.stats["lru_evictions"] += 1
            self._tbump(_.tenant, "lru_evictions")
            if self.demote_sink is not None and self.demote_sink(_):
                self.stats["demotions"] += 1   # spilled DOWN, not dropped
                self._tbump(_.tenant, "demotions")
            else:
                self.stats["evictions"] += 1
                self._tbump(_.tenant, "evictions")
            used = (self.tenant_used.get(int(entry.tenant), 0)
                    if self.tenant_used is not None else self.used_bytes)
        if entry.nbytes <= self._tenant_budget(entry.tenant):
            entry.state = CacheState.DRAM
            self.entries[entry.user_id] = entry
            self.used_bytes += entry.nbytes
            self._taccount(entry.tenant, entry.nbytes)
            self.stats["spills"] += 1
            self.stats["inserts"] += 1
            self._tbump(entry.tenant, "spills")
            self._tbump(entry.tenant, "inserts")
            return True
        return False

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        e = self.entries.get(user_id)
        if e is None:
            self.stats["dram_misses"] += 1
        else:
            self.entries.move_to_end(user_id)  # LRU touch
            self.stats["dram_hits"] += 1
            self._tbump(e.tenant, "dram_hits")
        return e

    def _remove(self, user_id: int) -> CacheEntry:
        e = self.entries.pop(user_id)
        self.used_bytes -= e.nbytes
        self._taccount(e.tenant, -e.nbytes)
        return e

    def take(self, user_id: int) -> Optional[CacheEntry]:
        """Remove an entry for ownership handoff (rebalancing churn):
        the DRAM copy migrates to the new owning host's tier instead of
        being dropped.  No hit/miss accounting — this is background
        migration, not a lookup."""
        e = self.entries.get(user_id)
        if e is not None:
            self._remove(user_id)
            self.stats["handoffs"] = self.stats.get("handoffs", 0) + 1
            self._tbump(e.tenant, "handoffs")
        return e

    # --- pseudo-pre-infer --------------------------------------------------
    def pseudo_pre_infer(self, user_id: int, hbm: HBMCacheStore,
                         now: float) -> Tuple[str, Optional[CacheEntry]]:
        """The cache-check step enqueued ahead of every ranking request.

        Returns (action, entry):
          'hbm'    — psi already resident, proceed to ranking directly;
          'reload' — leader: psi in DRAM, caller performs the (rate-
                     limited) DRAM->HBM reload;
          'wait'   — follower: another op for this user is in flight;
                     caller re-probes HBM after the leader completes;
          'miss'   — psi nowhere local: caller falls back (or the real
                     pre-infer computes it)."""
        e = hbm.lookup(user_id)
        if e is not None:
            return "hbm", e
        leader = self.flight.begin(user_id)
        if not leader:
            self.stats["redundant_avoided"] += 1
            return "wait", None
        d = self.lookup(user_id)
        if d is None:
            return "miss", None
        if not hbm.fits(d.nbytes, d.prefix_len, tenant=d.tenant):
            # permanently unpromotable (psi over the whole window
            # budget): drop the copy so we stop scheduling doomed
            # reloads — otherwise every request for this user would pay
            # a full H2D transfer just to be rejected and fall back
            self._remove(user_id)
            self.stats["unfit_dropped"] += 1
            self.stats["evictions"] += 1
            self._tbump(d.tenant, "evictions")
            return "miss", None
        if self.active_reloads >= self.cfg.max_reload_concurrency:
            self.stats["reload_throttled"] += 1
            return "miss", None
        # page-granular streaming: a partially resident window entry
        # resumes — only the missing suffix rides the H2D channel
        d.reload_tokens = hbm.missing_tokens(user_id, d.prefix_len)
        return "reload", d

    def complete_reload(self, user_id: int, hbm: HBMCacheStore, now: float
                        ) -> List[CacheEntry]:
        """Leader finished the H2D copy: promote DRAM entry into HBM.
        A paged window with a partially resident entry tops up just the
        missing tail pages (``PagedHBMStore._resume``)."""
        e = self.entries.get(user_id)
        evicted: List[CacheEntry] = []
        if e is not None:
            e.reload_tokens = None
            evicted = hbm.insert(user_id, e.value, e.nbytes, now,
                                 prefix_len=e.prefix_len, spans=e.spans,
                                 tenant=e.tenant)
            if hbm.resident(user_id) is None:
                # the window rejected the promotion: the reload is
                # wasted, but a TRANSIENTLY rejected copy (zombie-
                # pinched paged pool) must survive — dropping it would
                # turn every future request for this user into a cold
                # full-inference miss although psi still exists
                # locally.  A permanently unfit psi is dropped so no
                # further reloads get scheduled for it.
                if not hbm.fits(e.nbytes, e.prefix_len, tenant=e.tenant):
                    self._remove(user_id)
                    self.stats["unfit_dropped"] += 1
                    self.stats["evictions"] += 1
                    self._tbump(e.tenant, "evictions")
                return evicted
            self._remove(user_id)
            e.state = CacheState.HBM
            # the copy moved UP and out of this tier; a cold-revived
            # entry keeps its marker so the rank it unblocks classifies
            # as a cold hit
            hbm.entries[user_id].dram_backed = False
            hbm.entries[user_id].cold_sourced = e.cold_sourced
            self.stats["reloads"] += 1
            self.stats["promotions"] += 1
            self._tbump(e.tenant, "promotions")
        return evicted

    def finish(self, user_id: int):
        self.flight.end(user_id)
