"""RelayGR service: the full retrieval -> pre-processing -> ranking relay.

Wires the sequence-aware trigger (admission), the affinity-aware router
(placement) and the ranking instances (execution + expander) into one
request path.  This is the *functional* composition used by tests and the
live examples; the discrete-event simulator (repro.serving.simulator)
replays the same state machines under a virtual clock and concurrency to
measure P99/throughput at cluster scale.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

from repro.serving.metrics import SLOTracker

from .costmodel import GRCostModel
from .engine import InstanceConfig, RankingInstance, SimExecutor
from .router import AffinityRouter
from .trigger import Decision, SequenceAwareTrigger, TriggerConfig
from .types import HitKind, RankResult, Request, Stage, UserMeta


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    trigger: TriggerConfig = TriggerConfig()
    n_normal: int = 0                  # 0 -> derived from trigger cfg
    hbm_cache_bytes: float = 16e9
    dram_budget_bytes: float = 500e9
    long_seq_threshold: int = 0        # 0 -> use the trigger's risk test
                                       # (pre-processing decides the service)


class RelayGRService:
    def __init__(self, cfg: ServiceConfig, cost: GRCostModel,
                 executor_factory=None):
        self.cfg = cfg
        self.cost = cost
        self.trigger = SequenceAwareTrigger(cfg.trigger, cost)
        n_special = cfg.trigger.n_special
        n_normal = cfg.n_normal or (cfg.trigger.n_instances - n_special)
        self.special_names = [f"special-{i}" for i in range(n_special)]
        self.normal_names = [f"normal-{i}" for i in range(max(n_normal, 1))]
        self.router = AffinityRouter(self.special_names, self.normal_names)
        factory = executor_factory or (lambda name: SimExecutor(cost))
        self.instances: Dict[str, RankingInstance] = {}
        for name in self.special_names + self.normal_names:
            icfg = InstanceConfig(
                name=name, hbm_cache_bytes=cfg.hbm_cache_bytes,
                special=name.startswith("special"))
            icfg.dram.dram_budget_bytes = cfg.dram_budget_bytes
            self.instances[name] = RankingInstance(icfg, factory(name))
        self._req_ids = itertools.count()
        self.slo = SLOTracker()

    # --- stage 1: retrieval side-path ----------------------------------------
    def on_retrieval(self, meta: UserMeta, now: float
                     ) -> Optional[Request]:
        """Trigger assessment; returns the auxiliary pre-infer signal if
        the request was admitted (caller/simulator delivers it)."""
        signal = Request.pre_infer(next(self._req_ids), meta, now)
        target = self.router.route(signal)  # consistent hash on user key
        decision = self.trigger.admit(meta, target, now)
        if not decision.admitted:
            return None
        signal.body["target"] = target
        return signal

    def deliver_pre_infer(self, signal: Request, now: float
                          ) -> Dict[str, float]:
        inst = self.instances[signal.body["target"]]
        return inst.handle_pre_infer(signal, now)

    # --- stage 3: fine-grained ranking ----------------------------------------
    def on_rank(self, meta: UserMeta, now: float) -> RankResult:
        if self.cfg.long_seq_threshold:
            long_seq = meta.prefix_len >= self.cfg.long_seq_threshold
        else:
            long_seq = self.trigger.assess(meta).at_risk
        req = Request.rank(next(self._req_ids), meta, now=now,
                           long_sequence=long_seq)
        target = self.router.route(req)
        result = self.instances[target].handle_rank(req, now)
        self.slo.observe(now=now, e2e_ms=result.latency_ms,
                         hit=result.hit.value,
                         components=result.components)
        return result

    # --- synchronous end-to-end (live mode / tests) ----------------------------
    def submit(self, meta: UserMeta, now: float = 0.0) -> RankResult:
        signal = self.on_retrieval(meta, now)
        pre = {}
        if signal is not None:
            pre = self.deliver_pre_infer(signal, now)
        result = self.on_rank(meta, now + 1e-3)
        if pre:
            result.components["pre"] = pre["pre"]
        return result

    # --- observability -----------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        agg = {"trigger": dict(self.trigger.stats),
               "router": dict(self.router.stats),
               "slo": self.slo.summary(now=0.0)}
        inst = {}
        for name, i in self.instances.items():
            inst[name] = {**i.stats, "hbm": dict(i.hbm.stats),
                          "dram": dict(i.expander.stats)}
        agg["instances"] = inst
        return agg
