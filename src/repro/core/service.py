"""RelayGR service: the live-mode adapter over the shared RelayRuntime.

The full retrieval -> pre-processing -> ranking relay for live serving:
``submit()`` injects a request into the canonical event-driven state
machine (repro.core.runtime) and drains its cascade synchronously, so
live mode and the cluster simulator execute the *identical* lifecycle —
only the clock and the executor differ (see tests/test_runtime_parity).

The stage-level methods (``on_retrieval`` / ``deliver_pre_infer`` /
``on_rank``) remain for tests and ablations that drive the relay out of
band of the pipeline timing; they compose the same transition kernels.

``ServiceConfig`` is a deprecation shim — new code should build a
``RelayConfig`` via ``repro.core.runtime.relay_config``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

from .clock import Clock, WallClock
from .costmodel import GRCostModel
from .runtime import (ClusterConfig, RelayConfig, RelayRuntime,
                      as_relay_config, relay_config)
from .trigger import TriggerConfig
from .types import RankResult, Request, UserMeta


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """DEPRECATED: use ``relay_config(trigger=..., cluster=...)``."""
    trigger: TriggerConfig = TriggerConfig()
    n_normal: int = 0                  # 0 -> derived from trigger cfg
    hbm_cache_bytes: float = 16e9
    dram_budget_bytes: float = 500e9
    long_seq_threshold: int = 0        # 0 -> use the trigger's risk test
                                       # (pre-processing decides the service)

    def __post_init__(self):
        warnings.warn(
            "ServiceConfig is deprecated; build a RelayConfig with "
            "repro.core.runtime.relay_config(trigger=..., cluster=...)",
            DeprecationWarning, stacklevel=3)

    def to_relay(self) -> RelayConfig:
        return relay_config(
            trigger=self.trigger,
            cluster=ClusterConfig(
                n_normal=self.n_normal,
                hbm_cache_bytes=self.hbm_cache_bytes,
                dram_budget_bytes=self.dram_budget_bytes,
                long_seq_threshold=self.long_seq_threshold))


class RelayGRService:
    def __init__(self, cfg, cost: GRCostModel, executor_factory=None,
                 clock: Optional[Clock] = None):
        self.cfg = as_relay_config(cfg)
        self.cost = cost
        self.runtime = RelayRuntime(self.cfg, cost, executor_factory,
                                    clock=clock or WallClock())

    # --- adapter surface (state lives on the shared runtime) -------------------

    @property
    def trigger(self):
        return self.runtime.trigger

    @property
    def router(self):
        return self.runtime.router

    @property
    def topology(self):
        return self.runtime.topology

    def host_join(self, n_special: int = 1, n_normal: int = 0,
                  now: Optional[float] = None):
        return self.runtime.host_join(n_special, n_normal, now=now)

    def host_leave(self, name: str, now: Optional[float] = None) -> None:
        self.runtime.host_leave(name, now=now)

    @property
    def instances(self) -> Dict:
        return self.runtime.instances

    @property
    def slo(self):
        return self.runtime.slo

    @property
    def special_names(self):
        return self.runtime.special

    @property
    def normal_names(self):
        return self.runtime.normal

    # --- stage 1: retrieval side-path ----------------------------------------
    def on_retrieval(self, meta: UserMeta, now: float
                     ) -> Optional[Request]:
        """Trigger assessment; returns the auxiliary pre-infer signal if
        the request was admitted (caller/simulator delivers it)."""
        signal, _target = self.runtime.open_lifecycle(meta, now)
        return signal

    def deliver_pre_infer(self, signal: Request, now: float
                          ) -> Dict[str, float]:
        inst = self.instances[signal.body["target"]]
        return inst.handle_pre_infer(signal, now)

    # --- stage 3: fine-grained ranking ----------------------------------------
    def on_rank(self, meta: UserMeta, now: float) -> RankResult:
        req, target = self.runtime.bind_rank(meta, now)
        result = self.instances[target].handle_rank(req, now)
        self.slo.observe(now=now, e2e_ms=result.latency_ms,
                         hit=result.hit.value,
                         components=result.components)
        return result

    # --- synchronous end-to-end (live mode / tests) ----------------------------
    def submit(self, meta: UserMeta, now: Optional[float] = None
               ) -> RankResult:
        """Run one request through the full event-driven lifecycle
        (admission at arrival, pre-infer on the side path, ranking after
        the retrieval/preprocess slack).  ``latency_ms`` always equals
        ``sum(components.values())``."""
        return self.runtime.submit(meta, now)

    # --- observability -----------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        return self.runtime.stats()
