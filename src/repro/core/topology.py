"""Two-level cluster topology: host -> instance, with an explicit
owner map (paper §3.3 at fleet scale; MTServe/xGR-style placement).

The relay race spans pipeline stages that land on different machines,
so placement is a *topology* concern, not a single in-process hash
ring.  This module models the fleet as

  * ``Host`` — one server: a set of special (cache-holding) and normal
    ranking instances plus the server-local DRAM tier they share.  A
    host carries a *role*: ``"rank"`` servers hold psi and serve
    ranking; ``"prefill"`` servers (the disaggregated-prefill
    deployment, ``ClusterConfig.prefill_hosts > 0``) run only the
    pre-infer side path and SHIP every psi they produce to the user's
    owning rank host — they never own keys, so the owner map spans
    rank hosts only;
  * ``OwnerMap`` — which host *owns* a user key, decided by rendezvous
    (highest-random-weight) hashing over the host set.  Rendezvous
    hashing gives the minimal-disruption property the rebalance
    protocol relies on: a join moves only the keys the new host wins,
    a leave moves only the departed host's keys, and nothing else
    reshuffles;
  * ``ClusterTopology`` — epoch-versioned membership.  Every
    join/leave bumps the epoch and produces a new authoritative owner
    map; each host additionally carries its *local view* of the map,
    which trails the authoritative one until the deterministic
    gossip-style convergence steps propagate it (``gossip_step`` /
    ``converge``).  Routers route on the authoritative map; the views
    exist so churn tests and the simulator can model the stale-routing
    window between a membership change and cluster-wide agreement.

Within the owning host, producer/consumer rendezvous still uses the
per-host consistent-hash ring over that host's special instances
(``repro.core.router.AffinityRouter``).  With one host the owner map is
a constant function and the single ring is byte-identical to the
historical flat ring — ``hosts=1`` reproduces the single-process
deployment exactly (tests/test_topology.py).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional


def _h(data: str) -> int:
    """THE placement hash (8-byte sha256): the owner map, the per-host
    rings (repro.core.router re-exports this) and the random-placement
    ablation all draw from this one function, so their rendezvous
    formulas can never diverge."""
    return int.from_bytes(hashlib.sha256(data.encode()).digest()[:8], "big")


@dataclasses.dataclass
class Host:
    """One server in the fleet: instance names grouped by pool."""
    name: str
    special: List[str] = dataclasses.field(default_factory=list)
    normal: List[str] = dataclasses.field(default_factory=list)
    # dedicated pre-infer engines (only on role="prefill" hosts)
    prefill: List[str] = dataclasses.field(default_factory=list)
    role: str = "rank"                   # "rank" | "prefill"

    @property
    def instances(self) -> List[str]:
        return list(self.special) + list(self.normal) + list(self.prefill)


def stripe_hosts(special: List[str], normal: List[str],
                 n_hosts: int) -> List[Host]:
    """Round-robin the instance pools over ``n_hosts`` servers (instance
    i lands on host i % n_hosts), so every host gets a share of both
    pools when the pools are at least as large as the host count."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    hosts = [Host(name=f"host-{k}") for k in range(n_hosts)]
    for i, s in enumerate(special):
        hosts[i % n_hosts].special.append(s)
    for i, n in enumerate(normal):
        hosts[i % n_hosts].normal.append(n)
    return hosts


def make_prefill_hosts(n_hosts: int) -> List[Host]:
    """Dedicated pre-infer servers for the disaggregated-prefill
    deployment: one pooled prefill engine per host (its ``m_slots``
    model the host's NPU concurrency).  They join the topology with
    ``role="prefill"`` so the owner map never hands them keys."""
    return [Host(name=f"prefill-host-{k}", role="prefill",
                 prefill=[f"prefill-{k}"]) for k in range(int(n_hosts))]


class OwnerMap:
    """Rendezvous hashing over a host set, stamped with the membership
    epoch it was derived from.  ``owner(key)`` is a pure function of
    (members, key): every process that agrees on the membership agrees
    on every owner with no coordination."""

    def __init__(self, hosts: Iterable[str] = (), epoch: int = 0):
        self.hosts: List[str] = list(hosts)
        self.epoch = int(epoch)

    def owner(self, key) -> str:
        if not self.hosts:
            raise RuntimeError("owner map has no hosts")
        return max(self.hosts, key=lambda h: _h(f"{h}|{key}"))

    def copy(self) -> "OwnerMap":
        return OwnerMap(self.hosts, self.epoch)

    def __eq__(self, other) -> bool:
        return (isinstance(other, OwnerMap) and self.epoch == other.epoch
                and self.hosts == other.hosts)

    def __repr__(self) -> str:
        return f"OwnerMap(epoch={self.epoch}, hosts={self.hosts})"


class ClusterTopology:
    """Epoch-versioned host membership with per-host gossip views.

    The authoritative ``owner_map`` advances atomically on join/leave;
    each host's local view (``views[host]``) is only refreshed when the
    membership change is seeded at that host or when a gossip step
    pulls a newer map from a peer.  ``converge()`` runs deterministic
    gossip rounds (every host pulls from its successor in sorted
    order) until all views agree — O(n) rounds worst case for a rumor
    seeded at one host, and the round count is what the churn tests
    assert on."""

    def __init__(self, hosts: List[Host]):
        if not hosts:
            raise ValueError("topology needs at least one host")
        self.hosts: "OrderedDict[str, Host]" = OrderedDict(
            (h.name, h) for h in hosts)
        if not self._rank_names():
            raise ValueError("topology needs at least one rank host")
        self.owner_map = OwnerMap(self._rank_names(), epoch=0)
        self.views: Dict[str, OwnerMap] = {
            name: self.owner_map.copy() for name in self.hosts}
        # departed-host registry (name -> epoch at leave): a host that
        # left stops owning keys immediately, but resources that hand
        # off LAZILY (its cold-tier namespace) stay addressable until
        # their last entry re-homes on touch — consumers use this to
        # tell "departed" apart from "never existed"
        self.departed: Dict[str, int] = {}
        self._instance_host: Dict[str, str] = {}
        for h in hosts:
            for inst in h.instances:
                self._instance_host[inst] = h.name

    def _rank_names(self) -> List[str]:
        """Key-owning membership: prefill hosts run the side path only —
        they never own a user's cache lifecycle."""
        return [n for n, h in self.hosts.items() if h.role != "prefill"]

    # --- lookups ------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.owner_map.epoch

    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    def owner(self, key) -> Host:
        """Authoritative owning host for a user key."""
        return self.hosts[self.owner_map.owner(key)]

    def owner_in_view(self, viewer: str, key) -> str:
        """Owner according to ``viewer``'s possibly-stale local view —
        the host a router colocated with ``viewer`` would pick before
        gossip converges."""
        return self.views[viewer].owner(key)

    def host_of(self, instance: str) -> Optional[str]:
        return self._instance_host.get(instance)

    def all_special(self) -> List[str]:
        return [s for h in self.hosts.values() for s in h.special]

    def all_normal(self) -> List[str]:
        return [n for h in self.hosts.values() for n in h.normal]

    def all_prefill(self) -> List[str]:
        return [p for h in self.hosts.values() for p in h.prefill]

    # --- membership ---------------------------------------------------------

    def join(self, host: Host) -> None:
        """Add a host.  The new authoritative map (epoch + 1) is seeded
        at the joining host; every other view goes stale until gossip
        propagates it."""
        if host.name in self.hosts:
            raise ValueError(f"host {host.name!r} already in topology")
        self.hosts[host.name] = host
        for inst in host.instances:
            self._instance_host[inst] = host.name
        self.owner_map = OwnerMap(self._rank_names(), epoch=self.epoch + 1)
        self.views[host.name] = self.owner_map.copy()

    def leave(self, name: str) -> Host:
        """Remove a host.  The new map is seeded at the first surviving
        host (sorted order) — the rumor's deterministic origin."""
        if name not in self.hosts:
            raise KeyError(f"host {name!r} not in topology")
        if len(self.hosts) == 1:
            raise ValueError("cannot remove the last host")
        if self.hosts[name].role != "prefill" and len(self._rank_names()) == 1:
            raise ValueError("cannot remove the last rank host")
        host = self.hosts.pop(name)
        for inst in host.instances:
            self._instance_host.pop(inst, None)
        self.views.pop(name, None)
        self.owner_map = OwnerMap(self._rank_names(), epoch=self.epoch + 1)
        seed = sorted(self.hosts)[0]
        self.views[seed] = self.owner_map.copy()
        self.departed[name] = self.epoch
        return host

    def mark_departed(self, name: str) -> None:
        """Record a host as departed (idempotent; callers that remove
        hosts through a router wrapper rather than ``leave`` use this
        to keep the registry complete)."""
        self.departed.setdefault(name, self.epoch)

    def register_instance(self, instance: str, host: str,
                          special: bool) -> None:
        """Track an instance hot-added to an existing host (intra-host
        scale-up; the owner map is unaffected)."""
        h = self.hosts[host]
        (h.special if special else h.normal).append(instance)
        self._instance_host[instance] = host

    def unregister_instance(self, instance: str) -> None:
        host = self._instance_host.pop(instance, None)
        if host is not None and host in self.hosts:
            h = self.hosts[host]
            if instance in h.special:
                h.special.remove(instance)
            if instance in h.normal:
                h.normal.remove(instance)

    # --- gossip convergence --------------------------------------------------

    def converged(self) -> bool:
        return all(v == self.owner_map for v in self.views.values())

    def gossip_step(self) -> int:
        """One deterministic anti-entropy round: every host (sorted)
        pulls from its successor and keeps the newer map.  Returns the
        number of views that changed this round."""
        names = sorted(self.hosts)
        updated = 0
        fresh = {n: self.views[n] for n in names}
        for i, n in enumerate(names):
            peer = names[(i + 1) % len(names)]
            if fresh[peer].epoch > self.views[n].epoch:
                self.views[n] = fresh[peer].copy()
                updated += 1
        return updated

    def converge(self, max_rounds: int = 64) -> int:
        """Run gossip rounds until every view matches the authoritative
        map; returns the rounds taken (0 when already converged)."""
        rounds = 0
        while not self.converged():
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"gossip failed to converge in {max_rounds} rounds")
            if self.gossip_step() == 0:
                # no view holds the newest map (e.g. views were never
                # seeded): force-seed the deterministic origin
                self.views[sorted(self.hosts)[0]] = self.owner_map.copy()
        return rounds
