"""Affinity-aware router (paper §3.3), fleet-scale (two-level).

Converts late-binding placement into an early-binding contract: the
auxiliary pre-infer signal and the eventual ranking request for the same
user both carry ``consistency-hash-key: userID``.  Routing resolves the
key in two levels:

  1. **host** — the owner map (rendezvous hashing over the host set,
     ``repro.core.topology``) names the one server that owns this
     user's cache lifecycle;
  2. **instance** — the owning host's consistent-hash ring over *its*
     special instances picks the rendezvous instance.

Producer and consumer therefore meet at the same instance on the same
host with no coordination, across however many servers the fleet spans.
With a single host the owner map is constant and the per-host ring is
byte-identical to the historical flat ring, so ``hosts=1`` reproduces
the single-process router exactly.

Requests without the key (normal, short-sequence traffic) fall back to
standard policies (round-robin / least-connections / user-hash) inside
the owning host's normal pool.

Disaggregated prefill splits the rendezvous: when the topology carries
dedicated ``role="prefill"`` hosts, keyed PRE-INFER signals route to a
prefill engine (``route_pre``) while the eventual ranking request still
lands on the psi's owning rank host — the runtime ships the produced
psi cross-host to close the loop.
"""

from __future__ import annotations

import bisect
from typing import Dict, List, Optional

from .topology import ClusterTopology, Host, _h, stripe_hosts
from .types import HASH_KEY, Request, Stage


class ConsistentHashRing:
    def __init__(self, nodes: Optional[List[str]] = None, vnodes: int = 128):
        self.vnodes = vnodes
        self._ring: List[int] = []
        self._owner: Dict[int, str] = {}
        self.nodes: List[str] = []
        for n in nodes or []:
            self.add(n)

    def add(self, node: str):
        if node in self.nodes:
            return
        self.nodes.append(node)
        for v in range(self.vnodes):
            hv = _h(f"{node}#{v}")
            idx = bisect.bisect(self._ring, hv)
            self._ring.insert(idx, hv)
            self._owner[hv] = node

    def remove(self, node: str):
        if node not in self.nodes:
            return
        self.nodes.remove(node)
        self._ring = [hv for hv in self._ring if self._owner[hv] != node]
        self._owner = {hv: n for hv, n in self._owner.items() if n != node}

    def route(self, key) -> str:
        if not self._ring:
            raise RuntimeError("no nodes on the ring")
        hv = _h(str(key))
        idx = bisect.bisect(self._ring, hv) % len(self._ring)
        return self._owner[self._ring[idx]]


class AffinityRouter:
    """Two-tier, two-level routing.

    Keyed (special-pool) traffic: owner map -> owning host -> that
    host's consistent-hash ring over its special instances.  Unkeyed
    (normal-pool) traffic: owner map -> owning host -> a standard LB
    policy over the host's normal pool — ``round_robin``,
    ``least_connections`` or ``user_hash`` (session affinity: the same
    user keeps landing on the same normal instance, which is what
    production gateways do for feature-cache locality and what the
    cluster benchmarks are calibrated against).

    Construct from flat pools (a single implicit host — the historical
    deployment) or pass an explicit ``topology``."""

    def __init__(self, special: List[str], normal: List[str],
                 policy: str = "round_robin", vnodes: int = 128,
                 topology: Optional[ClusterTopology] = None):
        if topology is None:
            topology = ClusterTopology(
                stripe_hosts(list(special), list(normal), 1))
        self.topology = topology
        self.vnodes = vnodes
        self.policy = policy
        self.rings: Dict[str, ConsistentHashRing] = {
            name: ConsistentHashRing(host.special, vnodes=vnodes)
            for name, host in topology.hosts.items()}
        self._rr: Dict[str, int] = {name: 0 for name in topology.hosts}
        self._load: Dict[str, int] = {n: 0 for n in topology.all_normal()}
        self.stats = {"special": 0, "normal": 0, "prefill": 0}

    # --- single-host compatibility surface -----------------------------------

    @property
    def ring(self) -> ConsistentHashRing:
        """THE ring of the historical flat deployment.  Only meaningful
        with one host; multi-host callers must go through
        ``route_key`` / ``rings``."""
        if self.topology.n_hosts != 1:
            raise AttributeError(
                "router spans multiple hosts; use route_key()/rings")
        return next(iter(self.rings.values()))

    @property
    def normal(self) -> List[str]:
        return self.topology.all_normal()

    # --- routing -------------------------------------------------------------

    def route_key(self, key) -> str:
        """Resolve a user key: owning host, then that host's ring.  A
        host with no special instances (possible when the special pool
        is smaller than the host count) never owns keys — rendezvous
        re-runs over the special-bearing hosts, deterministically."""
        host = self.topology.owner(key)
        ring = self.rings.get(host.name)
        if ring is None or not ring.nodes:
            candidates = [n for n in self.topology.hosts
                          if self.rings[n].nodes]
            if not candidates:
                raise RuntimeError("no special instances on any host")
            name = max(candidates, key=lambda h: _h(f"{h}|{key}"))
            ring = self.rings[name]
        return ring.route(key)

    def route_pre(self, key) -> str:
        """Pre-infer signal placement.  Disaggregated deployments
        (topology carries ``role="prefill"`` hosts) rendezvous-hash the
        key over the dedicated prefill engines — deterministic and
        balanced, and deliberately NOT the owner ring: the producer
        computes on a prefill host and SHIPS psi to the owner at
        completion.  Co-located deployments fall back to the owner
        instance (producer and consumer share it)."""
        pool = self.topology.all_prefill()
        if not pool:
            return self.route_key(key)
        return max(pool, key=lambda p: _h(f"pre|{p}|{key}"))

    def route(self, request: Request) -> str:
        key = request.header.get(HASH_KEY)
        if (request.stage == Stage.PRE_INFER and key is not None
                and self.topology.all_prefill()):
            self.stats["prefill"] += 1
            return self.route_pre(key)
        if key is not None:
            self.stats["special"] += 1
            return self.route_key(key)
        return self.route_normal(request)

    def route_normal(self, request: Request) -> str:
        """The normal-pool LB path: unkeyed traffic, and the
        degradation target when churn leaves no special instance for
        keyed traffic to rendezvous at."""
        self.stats["normal"] += 1
        host = self.topology.owner(request.user.user_id)
        pool = host.normal or self.topology.all_normal()
        if self.policy == "user_hash":
            return pool[request.user.user_id % len(pool)]
        if self.policy == "least_connections" and self._load:
            node = min(pool, key=lambda n: self._load.get(n, 0))
            self._load[node] = self._load.get(node, 0) + 1
            return node
        node = pool[self._rr[host.name] % len(pool)]
        self._rr[host.name] += 1
        return node

    def release(self, node: str):
        if node in self._load:
            self._load[node] = max(0, self._load[node] - 1)

    # --- instance churn (affinity disruption -> fallback, not an error) -------

    def add_special(self, node: str, host: Optional[str] = None):
        """Hot-add a special instance.  Without an explicit host it
        joins the host with the fewest specials (deterministic
        tie-break: topology order) — the single-host case degenerates
        to the historical flat-ring add."""
        if host is None:
            host = min(self.topology.hosts,
                       key=lambda n: len(self.topology.hosts[n].special))
        if node not in self.topology.hosts[host].special:
            self.topology.register_instance(node, host, special=True)
        self.rings[host].add(node)

    def remove_special(self, node: str):
        host = self.topology.host_of(node)
        if host is None:
            return
        self.topology.unregister_instance(node)
        if host in self.rings:
            self.rings[host].remove(node)

    # --- host churn (owner-map epoch bumps; runtime performs the handoff) -----

    def add_host(self, host: Host) -> None:
        self.topology.join(host)
        self.rings[host.name] = ConsistentHashRing(host.special,
                                                   vnodes=self.vnodes)
        self._rr[host.name] = 0
        for n in host.normal:
            self._load.setdefault(n, 0)

    def remove_host(self, name: str) -> Host:
        host = self.topology.leave(name)
        self.rings.pop(name, None)
        self._rr.pop(name, None)
        for n in host.normal:
            self._load.pop(n, None)
        return host
