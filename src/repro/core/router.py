"""Affinity-aware router (paper §3.3).

Converts late-binding placement into an early-binding contract: the
auxiliary pre-infer signal and the eventual ranking request for the same
user both carry ``consistency-hash-key: userID``; the load balancer and
gateway apply consistent hashing on that key, so producer and consumer
rendezvous at the same special instance with no coordination.

Requests without the key (normal, short-sequence traffic) fall back to
standard policies (round-robin / least-connections).
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional

from .types import HASH_KEY, Request


def _h(data: str) -> int:
    return int.from_bytes(hashlib.sha256(data.encode()).digest()[:8], "big")


class ConsistentHashRing:
    def __init__(self, nodes: Optional[List[str]] = None, vnodes: int = 128):
        self.vnodes = vnodes
        self._ring: List[int] = []
        self._owner: Dict[int, str] = {}
        self.nodes: List[str] = []
        for n in nodes or []:
            self.add(n)

    def add(self, node: str):
        if node in self.nodes:
            return
        self.nodes.append(node)
        for v in range(self.vnodes):
            hv = _h(f"{node}#{v}")
            idx = bisect.bisect(self._ring, hv)
            self._ring.insert(idx, hv)
            self._owner[hv] = node

    def remove(self, node: str):
        if node not in self.nodes:
            return
        self.nodes.remove(node)
        self._ring = [hv for hv in self._ring if self._owner[hv] != node]
        self._owner = {hv: n for hv, n in self._owner.items() if n != node}

    def route(self, key) -> str:
        if not self._ring:
            raise RuntimeError("no nodes on the ring")
        hv = _h(str(key))
        idx = bisect.bisect(self._ring, hv) % len(self._ring)
        return self._owner[self._ring[idx]]


class AffinityRouter:
    """Two-tier routing: special pool via consistent hashing on the
    user-keyed header; normal pool via a standard LB policy —
    ``round_robin``, ``least_connections`` or ``user_hash`` (session
    affinity: the same user keeps landing on the same normal instance,
    which is what production gateways do for feature-cache locality and
    what the cluster benchmarks are calibrated against)."""

    def __init__(self, special: List[str], normal: List[str],
                 policy: str = "round_robin", vnodes: int = 128):
        self.ring = ConsistentHashRing(special, vnodes=vnodes)
        self.normal = list(normal)
        self.policy = policy
        self._rr = 0
        self._load: Dict[str, int] = {n: 0 for n in normal}
        self.stats = {"special": 0, "normal": 0}

    def route(self, request: Request) -> str:
        key = request.header.get(HASH_KEY)
        if key is not None:
            self.stats["special"] += 1
            return self.ring.route(key)
        self.stats["normal"] += 1
        if self.policy == "user_hash":
            return self.normal[request.user.user_id % len(self.normal)]
        if self.policy == "least_connections" and self._load:
            node = min(self._load, key=self._load.get)
            self._load[node] += 1
            return node
        node = self.normal[self._rr % len(self.normal)]
        self._rr += 1
        return node

    def release(self, node: str):
        if node in self._load:
            self._load[node] = max(0, self._load[node] - 1)

    # deployment churn (affinity disruption -> fallback path, not an error)
    def add_special(self, node: str):
        self.ring.add(node)

    def remove_special(self, node: str):
        self.ring.remove(node)
