"""RelayRuntime: the canonical event-driven relay-race state machine.

The paper's contribution is ONE request lifecycle

    trigger admission -> affinity routing -> pre-infer -> HBM window
    -> expander reload -> rank

and this module is its single implementation.  Historically the repo
carried it twice (a functional composition in ``core.service`` and a
discrete-event copy in ``serving.simulator``); both are now thin
adapters over this runtime, parameterized by

  * a ``Clock`` (``WallClock`` live / ``VirtualClock`` simulated),
  * an ``Executor`` (``LiveExecutor`` real JAX compute / ``SimExecutor``
    cost-model latencies — ``repro.core.executors`` registry),
  * named policies for trigger / router / expander
    (``repro.core.policies`` registry).

Resource contention is explicit and mode-independent: each instance has
M model slots (NPU concurrency, FIFO) and a bounded-concurrency H2D
channel (PCIe) shared by embedding uploads and DRAM->HBM reloads.
Out-of-order arrivals are handled by the per-user single-flight queue:
if ranking wins the race against its own pre-infer signal, the ranking
job parks until psi lands in HBM (at most one reload / compute per user
per burst).

Disaggregated prefill (``ClusterConfig.prefill_hosts > 0``) carves
dedicated side-path hosts out of the topology: admitted pre-infer
signals run on a prefill engine and the produced psi is SHIPPED
cross-host to its owning rank instance over per-host NIC links
(``GRCostModel.psi_transfer_ms`` — the same unified pricing rebalance
migrations use, with concurrent transfers contending for link
bandwidth).  A rank request racing its own shipment is served as a
miss (never parked on the network); the near-miss is counted in
``stats()["shipping"]["late_miss"]``.

Latency accounting invariant (tested in tests/test_runtime_parity.py):
for every completed request,

    RankResult.latency_ms == sum(RankResult.components.values())
                          == (t_done - t_rank_arrival) * 1e3

with components ``queue`` (slot/PCIe wait), ``pre`` (parked on the
user's own in-flight psi), ``load`` (DRAM->HBM copy) and ``rank``
(ranking compute) — the paper's Fig. 11c breakdown as critical-path
attribution.

Configuration is one composable ``RelayConfig`` (``relay_config(...)``)
collapsing the former ``ServiceConfig`` / ``SimConfig`` /
``PipelineConfig`` trio; the old names remain as deprecation shims.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict, deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.serving.batching import BatchAggregator, BatchingConfig, \
    PendingRank, prefill_grid
from repro.serving.metrics import SLOTracker

from .cache import HBMCacheStore, make_hbm_store
from .clock import Clock, VirtualClock, WallClock
from .coldstore import ColdStore, ColdStoreConfig
from .costmodel import GRCostModel
from .executors import Executor, get_executor
from .expander import DRAMExpander, ExpanderConfig
from .paging import DevicePagePool, PageLayout
from .policies import make_expander, make_router, make_trigger
from .topology import (ClusterTopology, Host, make_prefill_hosts,
                       stripe_hosts)
from .trigger import TriggerConfig
from .types import HitKind, RankResult, Request, UserMeta, reuse_spans


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """End-to-end recommendation pipeline timing (paper Fig. 2)."""
    retrieval_ms: float = 40.0
    preprocess_ms: float = 25.0
    trigger_signal_ms: float = 3.0       # retrieval-side-path delay
    pipeline_slo_ms: float = 135.0       # end-to-end P99 SLO
    rank_budget_ms: float = 50.0         # ranking-stage budget


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Instance pool + memory tiers + policy selection."""
    n_normal: int = 0                    # 0 -> trigger.n_instances - n_special
    hbm_cache_bytes: float = 16e9        # r1 * HBM per instance
    dram_budget_bytes: float = 500e9     # expander tier (0 disables)
    m_slots: int = 5                     # NPU model slots per instance
    pcie_concurrency: int = 4            # H2D channel width per instance
    max_batch: int = 0                   # >0 -> continuous micro-batching
    batch_wait_ms: float = 2.0           # aggregator flush deadline
    page_tokens: int = 0                 # >0 -> paged HBM window (pool pages)
    # device-resident page pool (requires page_tokens > 0): page data
    # lives in a device array mutated in place — inserts and reload
    # completions scatter only the fresh pages (donated update) and
    # rank_with_pages launches pass the pool by reference, so
    # per-launch host->device traffic is 0 instead of O(pool bytes).
    # Scores are bit-identical to the host-buffer pool either way
    # (tests/test_device_pool.py); the h2d ledger in ``stats()``
    # accounts the traffic.
    device_pool: bool = False
    # beyond-prefix segment reuse (RcLLM): the side path computes and
    # caches the prefix PLUS candidate-independent interior segments
    # (``UserMeta.seg_lens``) as a span-aware paged entry; ranking then
    # reuses every cached span and computes only the truly fresh
    # tokens.  Requires page_tokens > 0 (spans live in the page pool).
    # Disabled (the default) every trace is bit-identical to the
    # prefix-only path.
    segments: bool = False
    hosts: int = 1                       # servers the pools stripe over
    # >0 -> hierarchical cold tier (MTServe-style): one host-local SSD /
    # remote-store ColdStore per rank host under the DRAM expanders.
    # DRAM LRU evictions DEMOTE to cold (asynchronously, priced on the
    # host's cold link) instead of dropping, and a trigger-admitted
    # request for a cold-resident user starts an async cold->DRAM
    # PROMOTION on the pre path so the rank stage sees a DRAM hit / a
    # cheap partial reload instead of full re-inference.  0 (default)
    # disables the tier — bit-identical to the two-tier runtime.
    cold_budget_bytes: float = 0.0
    # cold-link congestion gate: when a host's cold link backlog (time
    # until the queue drains) exceeds this, new demotions are dropped
    # and new promotions skip straight to prefill compute — disk I/O
    # that would land hopelessly late must not be queued at all, or a
    # saturated SSD turns into an unbounded promise backlog
    cold_backlog_ms: float = 50.0
    # multi-tenant serving: partition the whole HBM->DRAM->cold
    # hierarchy into per-tenant byte/page quotas (equal shares) and give
    # the trigger per-tenant admission buckets + SLO classes.  tenants=1
    # (the default) builds NONE of this — bit-identical to the
    # single-workload runtime (tests/test_runtime_parity.py).
    tenants: int = 1
    rebalance: str = "handoff"           # churn policy: handoff | none
    # >0 -> disaggregated prefill: dedicate N hosts (one pooled prefill
    # engine each) to the pre-infer side path; produced psi is SHIPPED
    # cross-host to the owning rank host at insert time
    prefill_hosts: int = 0
    # NPU slots per prefill engine (0 -> m_slots).  The prefill tier is
    # provisioned independently of the rank tier: its engines carry the
    # WHOLE pool's side-path compute, so Eq. 3a's per-instance
    # admission rate scales with the engine's true slot count
    prefill_m_slots: int = 0
    # None -> serialize cross-host transfers on per-host NIC links iff
    # prefill_hosts > 0 (True/False force it); False reproduces the
    # legacy latency-only handoff pricing bit-for-bit
    nic_serialize: Optional[bool] = None
    relay_enabled: bool = True           # False -> baseline (no side path)
    long_seq_threshold: int = 0          # 0 -> trigger's risk test routes
    trigger_policy: str = "sequence-aware"
    router_policy: str = "affinity"
    expander_policy: str = "dram"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RelayConfig:
    """The one composable config for every relay-race deployment."""
    trigger: TriggerConfig = TriggerConfig()
    pipeline: PipelineConfig = PipelineConfig()
    cluster: ClusterConfig = ClusterConfig()


def relay_config(trigger: Optional[TriggerConfig] = None,
                 pipeline: Optional[PipelineConfig] = None,
                 cluster: Optional[ClusterConfig] = None,
                 **overrides) -> RelayConfig:
    """Build a ``RelayConfig``; extra keyword args are routed to every
    sub-config that declares the field, so callers can write
    ``relay_config(trigger=..., relay_enabled=False, hbm_cache_bytes=2e9)``.
    A field declared by several sub-configs (``m_slots`` lives on both
    the trigger — Eq. 3 capacity math — and the cluster — actual NPU
    slots) is set on all of them, keeping admission consistent with the
    instances it models.
    """
    parts = {"trigger": trigger or TriggerConfig(),
             "pipeline": pipeline or PipelineConfig(),
             "cluster": cluster or ClusterConfig()}
    for key, val in overrides.items():
        hit = False
        for slot in ("cluster", "pipeline", "trigger"):
            fields = {f.name for f in dataclasses.fields(parts[slot])}
            if key in fields:
                parts[slot] = dataclasses.replace(parts[slot], **{key: val})
                hit = True
        if not hit:
            raise TypeError(f"relay_config() got unknown field {key!r}")
    return RelayConfig(**parts)


def as_relay_config(cfg) -> RelayConfig:
    """Accept a RelayConfig or any legacy shim exposing ``to_relay()``."""
    if isinstance(cfg, RelayConfig):
        return cfg
    to_relay = getattr(cfg, "to_relay", None)
    if to_relay is not None:
        return to_relay()
    raise TypeError(f"expected RelayConfig (or a legacy ServiceConfig/"
                    f"SimConfig shim), got {type(cfg).__name__}")


def _reused_tokens(entry) -> int:
    """Cached tokens a hit actually reuses: the sum of the entry's span
    lengths (true valid tokens, not the page-padded total) for a
    segmented entry, the prefix length otherwise."""
    if entry is None:
        return 0
    if entry.spans:
        return int(sum(ln for _, ln in entry.spans))
    return int(entry.prefix_len)


# ---------------------------------------------------------------------------
# per-request trace record
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Record:
    """Per-request trace: one row per completed ranking request."""
    user_id: int
    t_arrival: float
    prefix_len: int = 0
    t_rank_arrival: float = 0.0
    t_done: float = 0.0
    rank_stage_ms: float = 0.0
    pre_ms: float = 0.0        # parked on the user's own in-flight psi
    load_ms: float = 0.0       # DRAM -> HBM reload on the critical path
    rank_ms: float = 0.0       # ranking compute
    queue_ms: float = 0.0      # slot / PCIe queueing
    hit: str = "miss"
    # beyond-prefix reuse accounting: cached tokens this rank actually
    # reused (prefix + interior segments on a hit; 0 on a miss) and the
    # request's total context (prefix + incr) — summary() reduces the
    # pair to the fleet-wide reused-token fraction
    reused_tokens: int = 0
    ctx_tokens: int = 0
    tenant: int = 0

    @property
    def e2e_ms(self) -> float:
        return (self.t_done - self.t_arrival) * 1e3


# ---------------------------------------------------------------------------
# ranking instance
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class InstanceConfig:
    name: str
    hbm_cache_bytes: float = 16e9       # r1 * HBM
    dram: ExpanderConfig = dataclasses.field(default_factory=ExpanderConfig)
    special: bool = True
    m_slots: int = 5
    pcie_concurrency: int = 4
    expander_policy: str = "dram"
    page_layout: Optional[PageLayout] = None   # paged HBM window geometry
    segments: bool = False              # span-aware (beyond-prefix) entries
    device_pool: bool = False           # device-resident page pool
    role: str = "rank"                  # "rank" | "prefill" (side path only)
    # multi-tenant byte partitions (tenant id -> share); None builds the
    # untenanted stores
    tenant_quota: Optional[Dict[int, int]] = None        # HBM window
    dram_tenant_quota: Optional[Dict[int, int]] = None   # private expander


class InstanceRuntime:
    """One accelerator-backed ranking instance (normal or special).

    Holds the memory tiers (HBM window + expander), the executor, and —
    when driven by a ``RelayRuntime`` event loop — the slot/PCIe
    resource state.  The *transition kernels* below are the single
    source of truth for how psi moves through the tiers; both the
    synchronous stage API (``handle_pre_infer`` / ``handle_rank``) and
    the event loop compose them.
    """

    def __init__(self, cfg: InstanceConfig, executor: Executor,
                 expander=None):
        self.cfg = cfg
        self.name = cfg.name
        self.special = cfg.special
        self.role = cfg.role
        self.segments = cfg.segments
        self.executor = executor
        # a live executor declares the page geometry of ITS model; the
        # cluster-level layout (from the cost model) covers sim mode.
        # A prefill engine holds no window at all (psi ships out on
        # completion), so it skips the paged-pool machinery.
        layout = (None if cfg.role == "prefill" else
                  getattr(executor, "page_layout", None) or cfg.page_layout)
        # device-resident pool: opted in by the deployment config OR by
        # a live executor built with device_pool=True (the executor owns
        # the device, so its choice wins when the config is silent)
        device = bool(cfg.device_pool
                      or getattr(executor, "device_pool", False))
        self.hbm = make_hbm_store(int(cfg.hbm_cache_bytes), layout,
                                  device_pool=device and layout is not None,
                                  tenant_quota=cfg.tenant_quota)
        if (isinstance(getattr(self.hbm, "pool", None), DevicePagePool)
                and hasattr(executor, "insert_pages")):
            # route the window's page-data movement (insert / resume /
            # free) through the executor's device-pool hooks
            self.hbm.device_hooks = executor
        if hasattr(self.hbm, "materialize_on_evict"):
            # no DRAM tier -> evictees are discarded, never spilled:
            # skip the dense gather on the eviction path
            self.hbm.materialize_on_evict = cfg.dram.dram_budget_bytes > 0
        # DRAM is host memory: a multi-host runtime passes the server's
        # shared expander; standalone instances (and the hosts=1
        # deployment, where affinity makes per-instance and per-host
        # tiers equivalent) own a private one
        self.expander = expander if expander is not None \
            else make_expander(cfg.expander_policy, cfg.dram,
                               tenant_quota=cfg.dram_tenant_quota)
        # continuous micro-batching: opted into by the executor carrying
        # a BatchingConfig + rank_group (the `batched` live executor or
        # a batching-enabled SimExecutor mirror)
        bcfg = getattr(executor, "batching", None)
        self.batcher: Optional[BatchAggregator] = (
            BatchAggregator(bcfg)
            if bcfg is not None and hasattr(executor, "rank_group")
            else None)
        # batched pre-inference (the side path): admitted prefills group
        # by the 64-token prefill grid and run as ONE jitted prefill
        self.pre_batcher: Optional[BatchAggregator] = (
            BatchAggregator(bcfg, key=lambda p:
                            ("pre", prefill_grid(p.prefix_len)))
            if bcfg is not None and hasattr(executor, "pre_infer_group")
            else None)
        self.stats = {"pre_infers": 0, "ranks": 0, "hbm_hits": 0,
                      "dram_hits": 0, "cold_hits": 0, "fallbacks": 0,
                      "spills": 0, "rejected_inserts": 0}
        # event-mode resource state (owned by the driving RelayRuntime)
        self.loop: Optional["RelayRuntime"] = None
        self.free_slots = cfg.m_slots
        self.queue: deque = deque()
        self.pcie_free = cfg.pcie_concurrency
        self.pcie_queue: deque = deque()
        self.inflight_pre: set = set()
        self.user_waiters: Dict[int, List[dict]] = defaultdict(list)
        self.busy_ms = 0.0

    # --- transition kernels (shared by both drive modes) --------------------

    def complete_pre(self, meta: UserMeta, psi: Any, nbytes: int,
                     now: float) -> None:
        """psi landed: insert into the HBM sliding window; evictees that
        already served their lifecycle spill to the DRAM reuse tier.
        ``psi is None`` marks a deduped pre-infer (psi already fully
        resident): renew the entry's lifecycle in place."""
        if psi is None:
            self.hbm.touch(meta.user_id, now)
            return
        # span-aware entries: the side path cached the prefix PLUS the
        # candidate-independent interior segments — record their layout
        # so the paged window pads each span to whole pages and ranking
        # knows the true reused-token count
        spans = reuse_spans(meta) if self.segments else None
        evicted = self.hbm.insert(meta.user_id, psi, nbytes, now,
                                  prefix_len=meta.prefix_len, spans=spans,
                                  tenant=meta.tenant)
        if meta.user_id not in self.hbm:
            # oversized psi rejected by the window (surfaced via
            # hbm.stats["rejected_inserts"]): the runtime must treat
            # this user as a miss — parked rankers wake, re-probe HBM,
            # and take the full-inference fallback
            self.stats["rejected_inserts"] += 1
        for e in evicted:
            if e.consumed:  # sliding-window exit -> DRAM reuse tier
                if self.expander.spill(e):
                    self.stats["spills"] += 1

    def cache_action(self, user_id: int, now: float):
        """Pseudo-pre-infer: the cache-check step in front of ranking."""
        return self.expander.pseudo_pre_infer(user_id, self.hbm, now)

    def resolve_wait(self, user_id: int):
        """Synchronous follower resolution: the leader's op completed
        within this drive step, so re-probe HBM exactly once."""
        self.expander.finish(user_id)
        e = self.hbm.lookup(user_id)
        return ("hbm", e) if e is not None else ("miss", None)

    def apply_reload(self, user_id: int, now: float):
        """Leader finished the H2D copy: promote DRAM entry into HBM."""
        self.expander.complete_reload(user_id, self.hbm, now)
        e = self.hbm.lookup(user_id)
        return ("hbm", e) if e is not None else ("miss", None)

    def classify_rank(self, user_id: int, action: str, entry,
                      load_ms: float) -> Tuple[HitKind, Any]:
        """THE hit classification + accounting for the rank step, shared
        by the unbatched (``exec_rank``) and batched (``_batch_rank``)
        paths so their traces can never desynchronize.  Returns
        (hit kind, psi to rank with — None means full-inference
        fallback) and consumes the HBM entry on a hit."""
        self.stats["ranks"] += 1
        if action == "hbm" and entry is not None:
            self.hbm.consume(user_id)
            if entry.cold_sourced:
                # this lifecycle was revived out of the cold tier — the
                # rank it unblocks is a cold hit; the flag then clears
                # so later (warm) lifecycles classify normally
                entry.cold_sourced = False
                hit = HitKind.COLD_HIT
                self.stats["cold_hits"] += 1
            else:
                hit = HitKind.DRAM_HIT if load_ms > 0 else HitKind.HBM_HIT
                self.stats["dram_hits" if load_ms > 0 else "hbm_hits"] += 1
            # paged store: pins the entry's pages until the launch
            # releases them, so a deferred batched group can never read
            # a page the sliding window recycled under it
            return hit, self.hbm.acquire_value(entry)
        # I1: never a remote fetch — local miss falls back to full
        # inference, preserving correctness at the cost of latency.
        self.stats["fallbacks"] += 1
        return HitKind.MISS_FALLBACK, None

    def exec_rank(self, req: Request, action: str, entry, comp: Dict[str, float],
                  now: float) -> RankResult:
        """Execute ranking for the resolved cache action and classify the
        hit.  ``comp`` carries the already-accumulated critical-path
        components; ``latency_ms`` is always their sum (invariant)."""
        meta = req.user
        hit, psi = self.classify_rank(meta.user_id, action, entry,
                                      comp.get("load", 0.0))
        if psi is not None:
            scores, rank_ms = self.executor.rank_cached(meta, psi)
            self.hbm.release_value(psi)
        else:
            scores, rank_ms = self.executor.rank_full(meta)
        comp["rank"] = rank_ms
        self.busy_ms += rank_ms
        return RankResult(
            req_id=req.req_id, user_id=meta.user_id, hit=hit, scores=scores,
            latency_ms=sum(comp.values()), components=comp,
            instance=self.name)

    # --- synchronous stage API (manual drive: tests, ablations) --------------

    def handle_pre_infer(self, req: Request, now: float) -> Dict[str, float]:
        meta = req.user
        self.stats["pre_infers"] += 1
        psi, nbytes, pre_ms = self.executor.pre_infer(meta)
        self.busy_ms += pre_ms
        self.complete_pre(meta, psi, nbytes, now)
        return {"pre": pre_ms}

    def handle_rank(self, req: Request, now: float) -> RankResult:
        meta = req.user
        comp: Dict[str, float] = {"pre": 0.0, "load": 0.0, "rank": 0.0,
                                  "queue": 0.0}
        action, entry = self.cache_action(meta.user_id, now)
        single_flight_open = action in ("reload", "miss")
        if action == "wait":
            action, entry = self.resolve_wait(meta.user_id)
        if action == "reload":
            comp["load"] = self.executor.reload_ms(
                meta, tokens=entry.reload_tokens)
            action, entry = self.apply_reload(meta.user_id, now)
        result = self.exec_rank(req, action, entry, comp, now)
        if single_flight_open:
            self.expander.finish(meta.user_id)
        return result

    # --- event-mode resource machinery ---------------------------------------

    def enqueue(self, job: dict, now: float) -> None:
        job.setdefault("t_enqueue", now)
        self.queue.append(job)
        self._maybe_start(now)

    def _maybe_start(self, now: float) -> None:
        while self.free_slots > 0 and self.queue:
            job = self.queue.popleft()
            self.free_slots -= 1
            self.loop.schedule(now, "job_start", inst=self, job=job)

    def release_slot(self, now: float) -> None:
        self.free_slots += 1
        self._maybe_start(now)
        if self.loop is None or self.free_slots <= 0 or self.queue:
            return
        if self.batcher is not None and self.batcher.pending:
            # work-conserving batching: an idle slot never waits out the
            # flush deadline while ranked work sits in the aggregator
            self.loop.schedule(now, "batch_drain", inst=self)
        elif self.pre_batcher is not None and self.pre_batcher.pending:
            # same discipline for the side path (ranked work first:
            # pre-inference is off the critical path)
            self.loop.schedule(now, "pre_drain", inst=self)

    def pcie_acquire(self, now: float, cb: Callable) -> None:
        if self.pcie_free > 0:
            self.pcie_free -= 1
            cb(now)
        else:
            self.pcie_queue.append(cb)

    def pcie_release(self, now: float) -> None:
        if self.pcie_queue:
            cb = self.pcie_queue.popleft()
            cb(now)
        else:
            self.pcie_free += 1


# ---------------------------------------------------------------------------
# the runtime
# ---------------------------------------------------------------------------


class RelayRuntime:
    """Event-driven engine for the relay-race lifecycle.

    Drive it either way:

      * ``run(arrivals)`` — enqueue a whole timed arrival stream and
        drain to completion (cluster simulation, benchmarks);
      * ``submit(meta, now)`` — inject one arrival and drain its event
        cascade synchronously, returning its ``RankResult`` (live
        serving; with a ``LiveExecutor`` the executor latencies are
        measured on real hardware and advance the logical timeline).

    Both paths run the identical handlers; only the clock and executor
    differ.  ``tests/test_runtime_parity.py`` asserts trace equality.
    """

    def __init__(self, cfg, cost: GRCostModel,
                 executor_factory: Optional[Callable[[str], Executor]] = None,
                 clock: Optional[Clock] = None):
        self.cfg = as_relay_config(cfg)
        self.cost = cost
        self.clock: Clock = clock if clock is not None else VirtualClock()
        cl = self.cfg.cluster
        # multi-tenant serving: tenants > 1 partitions every memory tier
        # into equal byte shares and layers per-tenant admission buckets
        # / SLO classes under the trigger.  The cluster knob is the
        # source of truth — sync the trigger config so one
        # ``relay_config(tenants=N)`` (or a bare ClusterConfig) is
        # enough.  tenants=1 leaves every config and store untouched.
        self.tenants = max(int(getattr(cl, "tenants", 1)), 1)
        if self.tenants != max(int(self.cfg.trigger.tenants), 1):
            self.cfg = dataclasses.replace(
                self.cfg, trigger=dataclasses.replace(
                    self.cfg.trigger, tenants=self.tenants))
        # disaggregated prefill: dedicated side-path hosts + psi shipped
        # cross-host to the owner — the shipping delay is priced into
        # the trigger's slack test (a late psi is a useless psi)
        self.disagg = cl.prefill_hosts > 0
        if cl.segments and cl.page_tokens <= 0:
            # spans live in the page pool (each span pads to whole
            # pages); a dense window has no span-addressable storage
            raise ValueError("ClusterConfig.segments requires a paged "
                             "HBM window (page_tokens > 0)")
        if cl.device_pool and cl.page_tokens <= 0:
            raise ValueError("ClusterConfig.device_pool requires a paged "
                             "HBM window (page_tokens > 0)")
        self.trigger = make_trigger(
            cl.trigger_policy, self.cfg.trigger, cost,
            ship_ms=((lambda m: cost.psi_transfer_ms(m.prefix_len,
                                                     cross_host=True))
                     if self.disagg else None))
        if cl.segments:
            # admission scores TOTAL reusable tokens (prefix + interior
            # segments), not just the prefix — the side path computes
            # and caches every span, so the slack deadline prices all
            # of them
            self.trigger.segments = True
        # risk test used for rank-stage routing; ablations may decouple
        # it from the admission trigger (e.g. admit-all + true-risk routes)
        self.route_trigger = self.trigger
        ns = self.cfg.trigger.n_special
        nn = max(cl.n_normal or (self.cfg.trigger.n_instances - ns), 1)
        self.special = [f"special-{i}" for i in range(ns)]
        self.normal = [f"normal-{i}" for i in range(nn)]
        # two-level fleet: the pools stripe over cl.hosts servers; the
        # owner map decides the owning host, the per-host ring the
        # instance.  hosts=1 degenerates to the historical flat router.
        # Prefill hosts join the topology with role="prefill": they run
        # the side path only and never own keys.
        fleet = stripe_hosts(self.special, self.normal, cl.hosts)
        fleet += make_prefill_hosts(cl.prefill_hosts)
        self.prefill = [p for h in fleet for p in h.prefill]
        self.topology = ClusterTopology(fleet)
        self.router = make_router(cl.router_policy, self.special, self.normal,
                                  seed=cl.seed, topology=self.topology)
        if executor_factory is not None:
            factory = executor_factory
        else:
            batching = (BatchingConfig(max_batch=cl.max_batch,
                                       max_wait_ms=cl.batch_wait_ms)
                        if cl.max_batch > 0 else None)
            factory = (lambda name, batching=batching:
                       get_executor("sim")(cost, batching=batching,
                                           page_tokens=cl.page_tokens,
                                           segments=cl.segments))
        self._factory = factory
        self._layout = (PageLayout.from_model_config(cost.cfg,
                                                     cl.page_tokens)
                        if cl.page_tokens > 0 else None)
        # DRAM is server memory: with several hosts, one shared expander
        # per host.  hosts=1 keeps the historical per-instance tier —
        # equivalent under affinity (each user is pinned to one
        # instance) and bit-compatible with single-process traces.
        self.host_expanders: Dict[str, DRAMExpander] = {}
        if cl.hosts > 1:
            for hname, h in self.topology.hosts.items():
                if h.role == "prefill":
                    continue      # no psi ever rests on a prefill host
                self.host_expanders[hname] = make_expander(
                    cl.expander_policy, ExpanderConfig(
                        dram_budget_bytes=cl.dram_budget_bytes,
                        max_reload_concurrency=cl.pcie_concurrency),
                    tenant_quota=self._tenant_quota_map(
                        cl.dram_budget_bytes))
        # hierarchical cold tier (MTServe-style, ROADMAP "Hierarchical
        # cache below DRAM"): one host-local SSD / remote-store
        # ColdStore per rank host.  DRAM LRU evictees demote into it
        # asynchronously (priced on the host's cold link, which
        # contends like the NIC) and a trigger-admitted visit from a
        # cold-resident user promotes the copy back up off the critical
        # path.  cold_budget_bytes=0 builds none of this — the
        # two-tier runtime stays bit-identical.
        self.cold_enabled = cl.cold_budget_bytes > 0
        self.cold_stores: Dict[str, ColdStore] = {}
        # a departed host's store: its entries re-home LAZILY (on next
        # touch), never eagerly at host_leave
        self._orphan_cold: Dict[str, ColdStore] = {}
        self.cold_links: Dict[str, Dict[str, float]] = {}
        # conservation holds at ALL event boundaries, not just after a
        # drain:  demotions == demote_landed + demote_dropped +
        # demote_inflight.  The inflight term covers the write window
        # between _demote (the copy left DRAM) and _on_demote_done (it
        # became cold-resident or was dropped) — without it a stats()
        # probe inside that window, e.g. while the DRAM source is being
        # handed off by concurrent churn, sees the family transiently
        # violated (tests/test_coldstore.py locks the interleaving).
        self.cold = {"demotions": 0, "demote_inflight": 0,
                     "demote_landed": 0,
                     "demote_dropped": 0, "demote_throttled": 0,
                     "promotions": 0, "promote_dropped": 0,
                     "promote_throttled": 0, "lazy_handoffs": 0,
                     "late_miss": 0, "ms": 0.0}
        self._promote_inflight: Dict[int, int] = {}
        self._promote_raced: set = set()
        if self.cold_enabled:
            for hname, h in self.topology.hosts.items():
                if h.role != "prefill":
                    self.cold_stores[hname] = ColdStore(
                        ColdStoreConfig(budget_bytes=cl.cold_budget_bytes),
                        tenant_quota=self._tenant_quota_map(
                            cl.cold_budget_bytes))
            # cold-aware admission: a cold-resident user's side path is
            # a promotion + reload, not a prefill — the trigger's slack
            # test prices THAT instead of the full pre-infer estimate
            self.trigger.cold_estimator = self._cold_pre_estimate
        self.instances: Dict[str, InstanceRuntime] = {}
        for host in self.topology.hosts.values():
            for name in host.instances:
                self.instances[name] = self._make_instance(
                    name, name.startswith("special"), host.name,
                    role=host.role)
        self.migration = {"entries": 0, "cross_host": 0, "intra_host": 0,
                          "ms": 0.0, "dropped": 0}
        if self.disagg:
            # Eq. 3a for the dedicated tier: each prefill engine admits
            # at q_m x ITS slot count (it carries the pool's whole side
            # path), bounded by the pool-wide cap; survival (Eqs. 1-2)
            # is still enforced per owner window by the pool bucket
            rate = self.cfg.trigger.q_m * (cl.prefill_m_slots
                                           or cl.m_slots)
            for name in self.prefill:
                self.trigger.instance_rates[name] = min(
                    rate, self.trigger.q_max)
        # cross-host psi shipping (disaggregated prefill) + the per-host
        # NIC link model both paths share.  nic_serialize=None -> links
        # contend exactly when the deployment is disaggregated; the
        # legacy latency-only pricing stays bit-identical otherwise.
        self.shipping = {"shipped": 0, "landed": 0, "deduped": 0,
                         "late_miss": 0, "dropped": 0, "forwarded": 0,
                         "coalesced": 0, "transfers": 0,
                         "bytes": 0, "ms": 0.0}
        self._ship_inflight: Dict[int, int] = {}
        self._ship_raced: set = set()
        self.nic_serialize = (self.disagg if cl.nic_serialize is None
                              else bool(cl.nic_serialize))
        self.nics: Dict[str, Dict[str, float]] = {}
        # monotone churn counters: departed names are never reused, so a
        # join can't silently overwrite a still-live instance
        self._next_special = ns
        self._next_normal = nn
        self.events: list = []
        self.records: List[Record] = []
        self._seq = itertools.count()
        self._req_ids = itertools.count()
        self.slo = SLOTracker(slo_ms=self.cfg.pipeline.pipeline_slo_ms)
        self.now = 0.0

    # --- lifecycle transitions shared with the manual stage API ---------------

    def open_lifecycle(self, meta: UserMeta, now: float
                       ) -> Tuple[Optional[Request], str]:
        """Stage 1 (retrieval side path): affinity binding + trigger
        admission.  Returns (pre-infer signal or None, bound target)."""
        signal = Request.pre_infer(next(self._req_ids), meta, now)
        target = self.router.route(signal)
        decision = self.trigger.admit(meta, target, now)
        if not decision.admitted:
            return None, target
        signal.body["target"] = target
        return signal, target

    def bind_rank(self, meta: UserMeta, now: float) -> Tuple[Request, str]:
        """Stage 3 entry: build the ranking request (user-keyed iff the
        sequence is long/at-risk and the relay is on) and route it."""
        cl = self.cfg.cluster
        if not cl.relay_enabled:
            long_seq = False          # baseline: no risk test, no key
        elif cl.long_seq_threshold:
            long_seq = meta.prefix_len >= cl.long_seq_threshold
        else:
            long_seq = self.route_trigger.assess(meta).at_risk
        req = Request.rank(next(self._req_ids), meta, now=now,
                           long_sequence=long_seq)
        return req, self.router.route(req)

    # --- event machinery ----------------------------------------------------

    def schedule(self, t: float, kind: str, **kw) -> None:
        heapq.heappush(self.events, (t, next(self._seq), kind, kw))

    def drain(self) -> None:
        while self.events:
            t, _, kind, kw = heapq.heappop(self.events)
            self.now = t
            self.clock.advance(t)
            getattr(self, f"_on_{kind}")(t, **kw)

    def run(self, arrivals: Iterable[Tuple[float, UserMeta]]
            ) -> Dict[str, float]:
        for t, meta in arrivals:
            self.schedule(t, "arrival", meta=meta)
        self.drain()
        return self.summary()

    def submit(self, meta: UserMeta, now: Optional[float] = None
               ) -> RankResult:
        """Live-mode entry: inject one arrival and run its cascade."""
        t = self.clock.now() if now is None else now
        box: List[RankResult] = []
        self.schedule(t, "arrival", meta=meta, sink=box.append)
        self.drain()
        return box[0]

    def _adopt(self, inst: InstanceRuntime) -> InstanceRuntime:
        # instances hot-swapped in by churn tests/deployments get wired
        # to this loop on first contact
        if inst.loop is not self:
            inst.loop = self
        return inst

    def _tenant_quota_map(self, budget: float) -> Optional[Dict[int, int]]:
        """Equal-share byte partition of ``budget`` over the configured
        tenants; None (build the untenanted store) for tenants=1 or a
        disabled tier."""
        if self.tenants <= 1 or budget <= 0:
            return None
        share = int(budget) // self.tenants
        return {t: share for t in range(self.tenants)}

    def _make_instance(self, name: str, special: bool, host: str,
                       role: str = "rank") -> InstanceRuntime:
        cl = self.cfg.cluster
        # a prefill engine never stores psi: no paged pool, no DRAM
        # tier — everything it produces ships to the owner immediately
        icfg = InstanceConfig(
            name=name, hbm_cache_bytes=cl.hbm_cache_bytes,
            special=special,
            m_slots=((cl.prefill_m_slots or cl.m_slots)
                     if role == "prefill" else cl.m_slots),
            pcie_concurrency=cl.pcie_concurrency,
            expander_policy=cl.expander_policy,
            page_layout=None if role == "prefill" else self._layout,
            segments=cl.segments,
            device_pool=cl.device_pool and role != "prefill", role=role,
            tenant_quota=(None if role == "prefill" else
                          self._tenant_quota_map(cl.hbm_cache_bytes)),
            dram_tenant_quota=(None if role == "prefill" else
                               self._tenant_quota_map(
                                   cl.dram_budget_bytes)))
        icfg.dram.dram_budget_bytes = (0.0 if role == "prefill"
                                       else cl.dram_budget_bytes)
        icfg.dram.max_reload_concurrency = cl.pcie_concurrency
        inst = InstanceRuntime(icfg, self._factory(name),
                               expander=self.host_expanders.get(host))
        inst.loop = self
        if self.cold_enabled and role != "prefill":
            # DRAM LRU evictees demote down to the host's cold store
            # (asynchronously, priced on the host cold link) instead of
            # dropping out of the hierarchy
            inst.expander.demote_sink = self._demote_sink(host)
        return inst

    # --- host membership churn (rebalancing, owner handoff) -------------------

    def host_join(self, n_special: int = 1, n_normal: int = 0,
                  now: Optional[float] = None) -> Host:
        """Add a server with fresh instances, bump the owner-map epoch,
        and (under ``rebalance="handoff"``) migrate every entry whose
        owner changed to its new owner — off the critical path, priced
        at the cross-host remote-fetch penalty."""
        now = self.now if now is None else now
        k = len(self.topology.hosts)
        while f"host-{k}" in self.topology.hosts:
            k += 1
        host = Host(name=f"host-{k}")
        for _ in range(n_special):
            name = f"special-{self._next_special}"
            self._next_special += 1
            host.special.append(name)
            self.special.append(name)
        for _ in range(n_normal):
            name = f"normal-{self._next_normal}"
            self._next_normal += 1
            host.normal.append(name)
            self.normal.append(name)
        if self.host_expanders:
            # per-host DRAM mode: the new server brings its own tier
            cl = self.cfg.cluster
            self.host_expanders[host.name] = make_expander(
                cl.expander_policy, ExpanderConfig(
                    dram_budget_bytes=cl.dram_budget_bytes,
                    max_reload_concurrency=cl.pcie_concurrency),
                tenant_quota=self._tenant_quota_map(cl.dram_budget_bytes))
        self.router.add_host(host)
        if self.cold_enabled:
            # the new server brings an (empty) cold store; entries the
            # join re-homes stay put until their next touch — the
            # rebalance walk below never moves cold copies eagerly
            self.cold_stores[host.name] = ColdStore(
                ColdStoreConfig(
                    budget_bytes=self.cfg.cluster.cold_budget_bytes),
                tenant_quota=self._tenant_quota_map(
                    self.cfg.cluster.cold_budget_bytes))
        for name in host.instances:
            self.instances[name] = self._make_instance(
                name, name in host.special, host.name)
        if self.cfg.cluster.rebalance == "handoff":
            self._rebalance(now)
        return host

    def host_leave(self, name: str, now: Optional[float] = None) -> None:
        """Remove a server.  Queued/parked work re-routes to the new
        owners; resident HBM/DRAM entries are HANDED OFF (never
        silently lost — ``premature_evictions`` stays 0 across churn)
        unless ``rebalance="none"`` models the naive silent-loss
        deployment."""
        now = self.now if now is None else now
        departing = list(self.topology.hosts[name].instances)
        departing_role = self.topology.hosts[name].role
        dep_expander = self.host_expanders.pop(name, None)
        self.router.remove_host(name)
        handoff = self.cfg.cluster.rebalance == "handoff"
        orphans: List[dict] = []
        for iname in departing:
            inst = self.instances.pop(iname)
            if iname in self.special:
                self.special.remove(iname)
            if iname in self.normal:
                self.normal.remove(iname)
            if iname in self.prefill:
                self.prefill.remove(iname)
            while inst.queue:
                orphans.append(inst.queue.popleft())
            for uid, jobs in list(inst.user_waiters.items()):
                for job in jobs:
                    # parked work keeps its accounting clock: the park
                    # interval until re-dispatch is still 'pre' time
                    job["rec"].pre_ms += (now - job.pop("t_park")) * 1e3
                    orphans.append(job)
                inst.user_waiters.pop(uid, None)
            for batcher in (inst.batcher, inst.pre_batcher):
                if batcher is None:
                    continue
                group = batcher.take_oldest()
                while group is not None:
                    orphans.append({"kind": "batch" if batcher is
                                    inst.batcher else "pre_batch",
                                    "group": group})
                    group = batcher.take_oldest()
            if handoff:
                for uid in list(inst.hbm.entries):
                    self._handoff_hbm(inst, uid, now)
                if dep_expander is None:       # per-instance DRAM tiers
                    for uid in list(inst.expander.entries):
                        self._handoff_dram(inst.expander, name, uid, now)
        if handoff and dep_expander is not None:
            for uid in list(dep_expander.entries):
                self._handoff_dram(dep_expander, name, uid, now)
        # Cold entries hand off LAZILY: unlike the HBM/DRAM walks above,
        # a departing host's cold store is parked as an orphan (still
        # addressable as a remote store) and each entry re-homes on its
        # NEXT TOUCH — eager eviction of a multi-TB SSD namespace at
        # host_leave would serialize the whole tier through one NIC.
        # Under rebalance="none" the namespace is simply lost with the
        # host (the naive deployment the handoff policy exists to beat).
        dep_cold = self.cold_stores.pop(name, None)
        if dep_cold is not None and dep_cold.entries and handoff:
            self._orphan_cold[name] = dep_cold
        self.topology.mark_departed(name)
        # re-dispatch orphaned work at its new owner (group members fall
        # back to plain jobs: their dead-host psi snapshots are gone, so
        # the new instance re-resolves the cache action from scratch)
        flat: List[dict] = []
        for job in orphans:
            if job["kind"] == "batch":
                flat.extend(w.payload for w in job["group"])
            elif job["kind"] == "pre_batch":
                flat.extend({"kind": "pre", "meta": w.meta}
                            for w in job["group"])
            else:
                flat.append(job)
        for job in flat:
            if job["kind"] == "pre":
                # side-path work follows its pool: a departing prefill
                # engine re-routes to a surviving one (rank owner only
                # when the prefill pool emptied); rank-host orphans stay
                # with the new owner, whose handed-off tiers serve them
                uid = job["meta"].user_id
                target = (self._pre_target(uid)
                          if departing_role == "prefill"
                          else self.router.route_key(uid))
            else:
                target = self.router.route(job["req"])
            inst = self._adopt(self.instances[target])
            if job["kind"] == "pre":
                inst.inflight_pre.add(job["meta"].user_id)
            inst.enqueue(job, now)

    # --- per-host NIC links (shipments and migrations contend) ----------------

    def _nic(self, host: Optional[str]) -> Dict[str, float]:
        """Link state of one host's NIC (lazily created; a departed
        host's link survives so in-flight drains stay accounted).
        Full duplex: egress (tx) and ingress (rx) serialize
        independently, like real NIC queues."""
        key = host or "<fabric>"
        nic = self.nics.get(key)
        if nic is None:
            nic = {"tx_free": 0.0, "rx_free": 0.0, "transfers": 0,
                   "bytes": 0, "busy_ms": 0.0, "wait_ms": 0.0}
            self.nics[key] = nic
        return nic

    def _link_transfer(self, now: float, src_host: Optional[str],
                       dst_host: Optional[str], nbytes: int,
                       prefix_len: int) -> Tuple[float, float]:
        """One cross-host psi transfer over the shipping fabric.
        Returns (arrival time, wall ms).  With ``nic_serialize`` the
        transfer occupies the sender's egress and then the receiver's
        ingress for its serialization window
        (``GRCostModel.link_occupancy_ms``) — a cut-through tandem, so
        concurrent shipments and rebalance migrations CONTEND for
        per-host link bandwidth; otherwise it degenerates to the
        legacy latency-only ``psi_transfer_ms`` pricing."""
        if not self.nic_serialize:
            ms = self.cost.psi_transfer_ms(prefix_len, cross_host=True)
            return now + ms / 1e3, ms
        nbytes = int(nbytes) or self.cost.kv_bytes(prefix_len)
        occ = self.cost.link_occupancy_ms(nbytes) / 1e3
        start_tx = now
        if src_host is not None:
            tx = self._nic(src_host)
            start_tx = max(now, tx["tx_free"])
            tx["tx_free"] = start_tx + occ
            tx["transfers"] += 1
            tx["bytes"] += nbytes
            tx["busy_ms"] += occ * 1e3
            tx["wait_ms"] += (start_tx - now) * 1e3
        start_rx = start_tx
        if dst_host is not None:
            rx = self._nic(dst_host)
            start_rx = max(start_tx, rx["rx_free"])
            rx["rx_free"] = start_rx + occ
            rx["transfers"] += 1
            rx["bytes"] += nbytes
            rx["busy_ms"] += occ * 1e3
            rx["wait_ms"] += (start_rx - start_tx) * 1e3
        arrival = start_rx + occ + self.cost.hw.net_rtt_ms / 1e3
        return arrival, (arrival - now) * 1e3

    def _handoff_hbm(self, inst: InstanceRuntime, uid: int,
                     now: float) -> None:
        """Migrate one HBM entry to the instance that now owns its key.
        The transfer rides the background shipping fabric (the unified
        ``psi_transfer_ms`` pricing + NIC link contention when the
        owner changed hosts, local H2D otherwise) and lands as a
        scheduled ``handoff_done`` event — a rank arriving inside the
        migration window falls back (I1: correctness first, speedup
        lost), it never fetches remotely on the critical path."""
        target = self.router.route_key(uid)
        if target == inst.name:
            return
        e = inst.hbm.extract(uid)
        if e is None:
            return
        cross = (self.topology.host_of(target)
                 != self.topology.host_of(inst.name))
        if e.value is None and e.page_table is None and e.dram_backed:
            # partially resident paged head: worthless off-instance; the
            # full DRAM copy migrates separately and covers this user
            self.migration["dropped"] += 1
            return
        arrival, ms = self._transfer(now, self.topology.host_of(inst.name),
                                     target, e.nbytes, e.prefix_len or 1,
                                     cross)
        self.migration["entries"] += 1
        self.migration["cross_host" if cross else "intra_host"] += 1
        self.migration["ms"] += ms
        self.schedule(arrival, "handoff_done", target=target,
                      entry=e, tier="hbm")

    def _transfer(self, now: float, src_host: Optional[str], target: str,
                  nbytes: int, prefix_len: int, cross: bool
                  ) -> Tuple[float, float]:
        """Price + schedule one background psi move (migration or
        shipment leg): cross-host moves ride the NIC fabric, intra-host
        moves re-cross the local H2D path."""
        if cross:
            return self._link_transfer(now, src_host,
                                       self.topology.host_of(target),
                                       nbytes, prefix_len)
        ms = self.cost.psi_transfer_ms(prefix_len, cross_host=False)
        return now + ms / 1e3, ms

    def _handoff_dram(self, expander, from_host: Optional[str], uid: int,
                      now: float) -> None:
        """Migrate one DRAM entry to the expander tier of the host that
        now owns its key."""
        target = self.router.route_key(uid)
        tgt_host = self.topology.host_of(target)
        tgt_exp = self.host_expanders.get(tgt_host)
        if tgt_exp is None:
            tgt_exp = self.instances[target].expander
        if tgt_exp is expander:
            return
        d = expander.take(uid)
        if d is None:
            return
        cross = from_host is None or from_host != tgt_host
        arrival, ms = self._transfer(now, from_host, target, d.nbytes,
                                     d.prefix_len or 1, cross)
        self.migration["entries"] += 1
        self.migration["cross_host" if cross else "intra_host"] += 1
        self.migration["ms"] += ms
        self.schedule(arrival, "handoff_done", target=target,
                      entry=d, tier="dram")

    def _rebalance(self, now: float) -> None:
        """After a membership change: walk every resident entry and hand
        off the ones whose owner moved.  Rendezvous hashing guarantees
        only keys won by the joining host (or orphaned by a leave)
        migrate — nothing else reshuffles."""
        for inst in list(self.instances.values()):
            for uid in list(inst.hbm.entries):
                self._handoff_hbm(inst, uid, now)
        seen: set = set()
        for hname, exp in list(self.host_expanders.items()):
            if id(exp) in seen:
                continue
            seen.add(id(exp))
            for uid in list(exp.entries):
                self._handoff_dram(exp, hname, uid, now)
        if not self.host_expanders:
            for inst in list(self.instances.values()):
                for uid in list(inst.expander.entries):
                    if self.router.route_key(uid) != inst.name:
                        self._handoff_dram(inst.expander, None, uid, now)

    def _on_handoff_done(self, t: float, target: str, entry, tier: str
                         ) -> None:
        inst = self.instances.get(target)
        if inst is None:
            # the destination churned away mid-flight: re-route once
            try:
                uid = entry.user_id
                retarget = self.router.route_key(uid)
            except Exception:
                self.migration["dropped"] += 1
                return
            if retarget == target or retarget not in self.instances:
                self.migration["dropped"] += 1
                return
            self.schedule(t, "handoff_done", target=retarget, entry=entry,
                          tier=tier)
            return
        if tier == "dram":
            if not inst.expander.spill(dataclasses.replace(entry)):
                self.migration["dropped"] += 1
            return
        evicted = inst.hbm.insert(entry.user_id, entry.value, entry.nbytes,
                                  t, prefix_len=entry.prefix_len,
                                  spans=entry.spans, tenant=entry.tenant)
        landed = inst.hbm.entries.get(entry.user_id)
        if landed is not None:
            # the entry continues its lifecycle: a consumed psi must not
            # later count as a premature eviction at its new home
            landed.consumed = entry.consumed
        else:
            # the target window rejected the insert (oversized psi or a
            # zombie-pinched pool): the migration did NOT land
            self.migration["dropped"] += 1
        for e in evicted:
            if e.consumed and inst.expander.spill(e):
                inst.stats["spills"] += 1
        self._wake_waiters(t, inst, entry.user_id)

    # --- cold tier (host SSD / remote psi store under DRAM) -------------------

    def _cold_link(self, host: str) -> Dict[str, float]:
        """Link state of one host's cold store (SSD namespace / remote-
        store share).  Unlike the full-duplex NIC this is ONE queue —
        reads and writes serialize against each other — and a departed
        host's link survives so lazy-handoff reads stay accounted."""
        link = self.cold_links.get(host)
        if link is None:
            link = {"free": 0.0, "transfers": 0, "bytes": 0,
                    "busy_ms": 0.0, "wait_ms": 0.0}
            self.cold_links[host] = link
        return link

    def _cold_transfer(self, now: float, host: str, nbytes: int,
                       prefix_len: int) -> Tuple[float, float]:
        """One cold-tier I/O (demotion write or promotion read) on
        ``host``'s cold link.  The uncontended cost is exactly the
        unified entry point ``GRCostModel.psi_transfer_ms(prefix_len,
        link="cold")``; this is its serialized form — the occupancy
        window charges the link so concurrent demotions and promotions
        contend for disk bandwidth, the same relationship
        ``_link_transfer`` has to the NIC pricing.  Returns (arrival
        time, wall ms)."""
        nbytes = int(nbytes) or self.cost.kv_bytes(prefix_len)
        occ = self.cost.link_occupancy_ms(nbytes, link="cold") / 1e3
        link = self._cold_link(host)
        start = max(now, link["free"])
        link["free"] = start + occ
        link["transfers"] += 1
        link["bytes"] += nbytes
        link["busy_ms"] += occ * 1e3
        link["wait_ms"] += (start - now) * 1e3
        arrival = start + occ + self.cost.hw.cold_rtt_ms / 1e3
        return arrival, (arrival - now) * 1e3

    def _demote_sink(self, host: str):
        """The hook wired into a host's DRAM expander: LRU evictees are
        offered here; True means the copy entered the demotion pipeline
        (counted by the expander as a demotion, not an eviction)."""
        def sink(entry, host=host):
            return self._demote(self.now, host, entry)
        return sink

    def _cold_backlog_ok(self, now: float, host: str) -> bool:
        """Congestion gate: False when the host's cold link is backed
        up past ``cold_backlog_ms`` of queued I/O."""
        link = self._cold_link(host)
        return (link["free"] - now) * 1e3 \
            <= self.cfg.cluster.cold_backlog_ms

    def _promote_viable(self, now: float, meta: UserMeta, src_host: str,
                        dst_host: Optional[str], *,
                        burned_ms: float = 0.0) -> bool:
        """Deadline test for a candidate promotion: queued link backlog
        + cold read (+ NIC leg for a foreign/departed source) + the
        DRAM->HBM reload must fit inside what is LEFT of the
        pre-signal -> rank window (``burned_ms`` is the queue time the
        pre job already spent), otherwise the psi lands behind its own
        rank request and the revival was pure wasted I/O."""
        link = self._cold_link(src_host)
        est = max(0.0, link["free"] - now) * 1e3 \
            + self.cost.psi_transfer_ms(meta.prefix_len, link="cold") \
            + self.cost.dram_load_ms(meta.prefix_len)
        if src_host != dst_host:
            est += self.cost.psi_transfer_ms(meta.prefix_len,
                                             cross_host=True)
        pp = self.cfg.pipeline
        return est <= (pp.retrieval_ms + pp.preprocess_ms
                       - pp.trigger_signal_ms - burned_ms)

    def _demote(self, now: float, host: str, entry) -> bool:
        store = self.cold_stores.get(host)
        if store is None or entry.value is None \
                or entry.nbytes > store.cfg.budget_bytes:
            return False
        if not self._cold_backlog_ok(now, host):
            self.cold["demote_throttled"] += 1
            return False
        arrival, ms = self._cold_transfer(now, host, entry.nbytes,
                                          entry.prefix_len or 1)
        self.cold["demotions"] += 1
        self.cold["demote_inflight"] += 1
        self.cold["ms"] += ms
        self.schedule(arrival, "demote_done", host=host, entry=entry)
        return True

    def _on_demote_done(self, t: float, host: str, entry) -> None:
        # the write completed: the copy becomes cold-resident NOW (a
        # promotion probe during the in-flight window missed — the disk
        # copy was not readable yet).  Resolve the inflight term FIRST
        # so the landed/dropped increment below keeps the conservation
        # family exact at this very event boundary.
        self.cold["demote_inflight"] -= 1
        store = self.cold_stores.get(host) or self._orphan_cold.get(host)
        if store is None or not store.insert(entry):
            self.cold["demote_dropped"] += 1
            return
        self.cold["demote_landed"] += 1
        # single cold ownership: a fresher demotion supersedes any stale
        # copy the same user left on another host's store (e.g. before
        # a rebalance moved their key)
        for s in list(self.cold_stores.values()) \
                + list(self._orphan_cold.values()):
            if s is not store:
                s.drop(entry.user_id)

    def _cold_find(self, uid: int, prefer: Optional[str] = None):
        """Locate a user's cold copy without accounting: the preferred
        (destination) host's store first, then the other live stores,
        then orphaned stores of departed hosts.  Returns (src_host,
        store) or None."""
        if prefer is not None:
            store = self.cold_stores.get(prefer)
            if store is not None and store.peek(uid) is not None:
                return prefer, store
        for host, store in self.cold_stores.items():
            if host != prefer and store.peek(uid) is not None:
                return host, store
        for host, store in self._orphan_cold.items():
            if store.peek(uid) is not None:
                return host, store
        return None

    def _cold_pre_estimate(self, meta: UserMeta) -> Optional[float]:
        """Admission-time side-path estimate for a cold-resident user:
        a promotion read + DRAM->HBM reload replaces the full prefill
        compute (plus a NIC leg when the copy sits on a foreign or
        departed host).  None when the user has no cold copy."""
        found = self._cold_find(meta.user_id)
        if found is None:
            return None
        ms = (self.cost.psi_transfer_ms(meta.prefix_len, link="cold")
              + self.cost.dram_load_ms(meta.prefix_len))
        src_host, _ = found
        owner_host = self.topology.host_of(self.router.route_key(
            meta.user_id))
        if src_host != owner_host:
            ms += self.cost.psi_transfer_ms(meta.prefix_len,
                                            cross_host=True)
        return ms

    def _promote_open(self, uid: int) -> None:
        self._promote_inflight[uid] = self._promote_inflight.get(uid, 0) + 1

    def _promote_close(self, uid: int) -> None:
        n = self._promote_inflight.get(uid, 0)
        if n <= 1:
            self._promote_inflight.pop(uid, None)
        else:
            self._promote_inflight[uid] = n - 1

    def _start_promotion(self, t: float, inst: InstanceRuntime,
                         meta: UserMeta, src_host: str, store) -> None:
        """Async cold->DRAM promotion on the pre path (the relay's side
        lane): a cold read on the source host's cold link, plus one NIC
        fabric leg when the copy lives on a foreign or departed host —
        the LAZY handoff moment: the entry re-homes now, on touch, not
        eagerly at host_leave."""
        uid = meta.user_id
        dst_host = self.topology.host_of(inst.name)
        if src_host == dst_host:
            entry = store.take(uid)          # store counts a promotion
            arrival, ms = self._cold_transfer(t, src_host, entry.nbytes,
                                              entry.prefix_len or 1)
        else:
            entry = store.extract(uid)       # extract != evict: handoff
            read_t, ms1 = self._cold_transfer(t, src_host, entry.nbytes,
                                              entry.prefix_len or 1)
            arrival, ms2 = self._link_transfer(read_t, src_host, dst_host,
                                               entry.nbytes,
                                               entry.prefix_len or 1)
            ms = ms1 + ms2
            self.cold["lazy_handoffs"] += 1
            if not store.entries:
                # last lazily handed-off entry left a departed host's
                # namespace: release the orphan
                self._orphan_cold.pop(src_host, None)
        self.cold["promotions"] += 1
        self.cold["ms"] += ms
        self._promote_open(uid)
        self.schedule(arrival, "promote_done", inst=inst, meta=meta,
                      entry=entry)
        # the disk read needs no NPU: give the model slot back for the
        # whole cold-link wait (the pre lifecycle stays open via
        # inflight_pre) — holding it would let a congested cold link
        # starve the instance of compute slots
        inst.release_slot(t)

    def _on_promote_done(self, t: float, inst: InstanceRuntime,
                         meta: UserMeta, entry) -> None:
        uid = meta.user_id
        self._promote_close(uid)
        entry.cold_sourced = True
        if self.instances.get(inst.name) is not inst:
            # the destination churned away mid-promotion: the copy
            # re-homes to the current owner's DRAM tier instead
            inst.inflight_pre.discard(uid)
            try:
                target = self.router.route_key(uid)
            except Exception:
                self.cold["promote_dropped"] += 1
                return
            self.schedule(t, "handoff_done", target=target, entry=entry,
                          tier="dram")
            return
        if not inst.expander.spill(entry):
            # the DRAM tier rejected the promoted copy: the revival is
            # lost and the pre lifecycle closes as a miss (the model
            # slot went back when the promotion started)
            self.cold["promote_dropped"] += 1
            inst.inflight_pre.discard(uid)
            self._wake_waiters(t, inst, uid)
            return
        # continue exactly like the DRAM pre-reload path: stream the
        # copy into the HBM window over PCIe so the rank stage sees a
        # resident (cold-sourced) psi
        d = inst.expander.entries[uid]
        d.reload_tokens = inst.hbm.missing_tokens(uid, d.prefix_len)
        ms = inst.executor.reload_ms(meta, tokens=d.reload_tokens)

        def start(t2, inst=inst, meta=meta, ms=ms):
            self.schedule(t2 + ms / 1e3, "pre_reload_done", inst=inst,
                          meta=meta, ms=ms, slotless=True)
        inst.pcie_acquire(t, start)

    # --- pipeline stage handlers ----------------------------------------------

    def _on_arrival(self, t: float, meta: UserMeta, sink=None) -> None:
        rec = Record(user_id=meta.user_id, t_arrival=t,
                     prefix_len=meta.prefix_len,
                     ctx_tokens=meta.prefix_len + meta.incr_len,
                     tenant=getattr(meta, "tenant", 0))
        pp = self.cfg.pipeline
        if self.cfg.cluster.relay_enabled:
            signal, target = self.open_lifecycle(meta, t)
            if signal is not None:
                self.schedule(t + pp.trigger_signal_ms / 1e3, "pre_signal",
                              meta=meta, target=target)
        t_rank = t + (pp.retrieval_ms + pp.preprocess_ms) / 1e3
        self.schedule(t_rank, "rank_arrival", meta=meta, rec=rec, sink=sink)

    def _on_pre_signal(self, t: float, meta: UserMeta, target: str) -> None:
        uid = meta.user_id
        if self.disagg and target in self.instances \
                and self.instances[target].role == "prefill":
            # psi already host-local at the OWNER (resident window,
            # DRAM copy, or a cold-tier copy a promotion can revive)?
            # Then the colocated side path — lifecycle touch, local
            # reload, or cold promotion — handles it without burning
            # prefill compute or a NIC shipment
            owner = self.router.route_key(uid)
            oinst = self.instances.get(owner)
            if oinst is not None and (
                    oinst.hbm.resident(uid) is not None
                    or uid in oinst.expander.entries
                    or (self.cold_enabled
                        and self._cold_find(uid) is not None)):
                target = owner
        if target not in self.instances:
            # the bound instance churned away between binding and the
            # signal landing: rebind to the current owner
            target = self._pre_target(uid)
        inst = self._adopt(self.instances[target])
        inst.inflight_pre.add(uid)
        if inst.role == "prefill":
            # the owner-side rank path must see the side path as "in
            # flight over the network", not "in flight locally": a rank
            # racing the shipment is served as a miss, never parked
            self._ship_open(uid)
        # t_signal rides along so deadline-aware side-path decisions
        # (the cold promotion's viability test) can subtract the queue
        # time already burned from the pre-signal -> rank window
        inst.enqueue({"kind": "pre", "meta": meta, "t_signal": t}, t)

    def _pre_target(self, uid: int) -> str:
        """Current side-path placement for a user: a prefill engine in
        the disaggregated deployment, the owning rank instance
        otherwise."""
        if self.disagg:
            target = self.router.route_pre(uid)
            if target in self.instances:
                return target
        return self.router.route_key(uid)

    def _ship_open(self, uid: int) -> None:
        self._ship_inflight[uid] = self._ship_inflight.get(uid, 0) + 1

    def _ship_close(self, uid: int) -> None:
        n = self._ship_inflight.get(uid, 0) - 1
        if n <= 0:
            self._ship_inflight.pop(uid, None)
        else:
            self._ship_inflight[uid] = n

    # --- membership-churn events (mid-stream join/leave in simulation) --------

    def _on_host_join(self, t: float, n_special: int = 1,
                      n_normal: int = 0) -> None:
        self.host_join(n_special=n_special, n_normal=n_normal, now=t)

    def _on_host_leave(self, t: float, name: str) -> None:
        self.host_leave(name, now=t)

    def _on_rank_arrival(self, t: float, meta: UserMeta, rec: Record,
                         sink=None) -> None:
        req, target = self.bind_rank(meta, t)
        rec.t_rank_arrival = t
        inst = self._adopt(self.instances[target])
        inst.enqueue({"kind": "rank", "req": req, "rec": rec, "sink": sink}, t)

    # --- job execution ----------------------------------------------------------

    def _on_job_start(self, t: float, inst: InstanceRuntime, job: dict
                      ) -> None:
        if job["kind"] == "pre":
            self._start_pre(t, inst, job["meta"],
                            t_signal=job.get("t_signal"))
            return
        if job["kind"] == "batch":
            self._start_batch(t, inst, job["group"])
            return
        if job["kind"] == "pre_batch":
            self._start_pre_batch(t, inst, job["group"])
            return
        req: Request = job["req"]
        rec: Record = job["rec"]
        meta = req.user
        uid = meta.user_id
        rec.queue_ms += (t - job.pop("t_enqueue")) * 1e3
        if not self.cfg.cluster.relay_enabled:
            self._finish_rank(t, inst, job, "miss", None)
            return
        action, entry = inst.cache_action(uid, t)
        if action == "hbm":
            self._finish_rank(t, inst, job, "hbm", entry)
        elif action == "wait":
            # psi is in flight for this user (a reload led by an
            # earlier rank job — 'wait' implies an open leader): drop
            # our follower increment and park on the single-flight
            # queue; the slot goes back and the leader's completion
            # wakes us into an HBM hit
            inst.expander.finish(uid)
            self._park(t, inst, uid, job)
        elif action == "reload":
            # page-granular: a partially resident entry resumes — only
            # the missing pages ride the H2D channel
            ms = inst.executor.reload_ms(meta, tokens=entry.reload_tokens)

            def start_reload(t2, inst=inst, job=job, ms=ms, t_req=t):
                # PCIe channel wait shows up as queueing, not load
                job["rec"].queue_ms += (t2 - t_req) * 1e3
                self.schedule(t2 + ms / 1e3, "reload_done", inst=inst,
                              job=job, ms=ms)

            inst.pcie_acquire(t, start_reload)
        else:  # miss
            if self._promote_inflight.get(uid):
                # promotion-vs-deadline race: the psi is still on the
                # disk path (cold read / NIC leg) — serve the miss NOW,
                # mirroring the shipping late_miss semantics, rather
                # than stall the rank on an I/O-bound arrival; the
                # promotion still lands for future reuse
                self.cold["late_miss"] += 1
                self._promote_raced.add(uid)
                inst.expander.finish(uid)
                self._finish_rank(t, inst, job, "miss", None)
            elif uid in inst.inflight_pre:
                # out-of-order: rank arrived before its pre-infer finished
                inst.expander.finish(uid)
                self._park(t, inst, uid, job)
            else:
                if self._ship_inflight.get(uid):
                    # shipping-vs-deadline race: the psi is still on the
                    # wire (or in prefill compute) — serve the miss NOW
                    # rather than stall on an NIC-contended arrival; the
                    # shipment still lands for future reuse (no
                    # double-rank: nobody is parked)
                    self.shipping["late_miss"] += 1
                    self._ship_raced.add(uid)
                inst.expander.finish(uid)
                self._finish_rank(t, inst, job, "miss", None)

    def _start_pre(self, t: float, inst: InstanceRuntime, meta: UserMeta,
                   t_signal: Optional[float] = None) -> None:
        uid = meta.user_id
        if inst.role == "prefill":
            owner = self.instances.get(self.router.route_key(uid))
            if owner is not None and owner.hbm.resident(uid) is not None:
                # dedup across the split: psi became resident at the
                # owner while this signal queued — renew its lifecycle
                # there, ship nothing (the refresh costs no NIC bytes)
                inst.inflight_pre.discard(uid)
                self._ship_close(uid)
                self.shipping["deduped"] += 1
                self._adopt(owner).hbm.touch(uid, t)
                inst.release_slot(t)
                return
        # dedup: psi already local (HBM or DRAM) -> pseudo step only.
        # Higher DRAM hit rates therefore reduce pre-inference work and
        # NPU utilization (paper Fig. 14b).
        if inst.hbm.resident(uid) is not None:
            # psi=None marks the in-place lifecycle renewal (touch)
            self.schedule(t, "pre_done", inst=inst, meta=meta,
                          psi=None, nbytes=0)
            return
        d = inst.expander.entries.get(uid)
        if d is not None:
            d.reload_tokens = inst.hbm.missing_tokens(uid, d.prefix_len)
            ms = inst.executor.reload_ms(meta, tokens=d.reload_tokens)

            def start(t2, inst=inst, meta=meta, ms=ms):
                self.schedule(t2 + ms / 1e3, "pre_reload_done",
                              inst=inst, meta=meta, ms=ms)

            inst.pcie_acquire(t, start)
            return
        if self.cold_enabled and inst.role != "prefill":
            dst_host = self.topology.host_of(inst.name)
            found = self._cold_find(uid, prefer=dst_host)
            # serving-path probe accounting (the admission estimator
            # peeks without counting): hit on the store that holds the
            # copy, miss against the destination host's store
            if found is not None:
                found[1].stats["hits"] += 1
            elif dst_host in self.cold_stores:
                self.cold_stores[dst_host].stats["misses"] += 1
            if found is not None:
                burned = 0.0 if t_signal is None else (t - t_signal) * 1e3
                if self._promote_viable(t, meta, found[0],
                                        self.topology.host_of(inst.name),
                                        burned_ms=burned):
                    # cold-resident: an async promotion (cold read ->
                    # DRAM -> PCIe reload) replaces the prefill
                    # compute; the rank either finds the revived psi
                    # or races it and is served as a miss (never
                    # stalls on the disk)
                    self._start_promotion(t, inst, meta, *found)
                    return
                # the read would land after the rank (link backlog +
                # transfer + reload exceed the pre-signal->rank
                # window): a doomed promotion converts a would-be
                # compute hit into a full miss — recompute instead
                self.cold["promote_throttled"] += 1
        if inst.pre_batcher is not None:
            self._batch_pre(t, inst, meta)
            return
        inst.stats["pre_infers"] += 1
        psi, nbytes, ms = inst.executor.pre_infer(meta)
        inst.busy_ms += ms
        self.schedule(t + ms / 1e3, "pre_done", inst=inst, meta=meta,
                      psi=psi, nbytes=nbytes)

    # --- batched pre-inference (the side path, grouped by prefill grid) -------

    def _batch_pre(self, t: float, inst: InstanceRuntime, meta: UserMeta
                   ) -> None:
        """Admitted prefill under batching: park in the pre aggregator
        (keyed by the 64-token prefill grid) and follow the same
        work-conserving discipline as the rank path — an uncontended
        slot launches the group of one immediately, so spaced traces
        stay bit-identical to the unbatched side path; under contention
        admitted users share ONE jitted prefill per grid, lifting the
        admission ceiling the per-user side path imposed."""
        work = PendingRank(user_id=meta.user_id, psi=None,
                           prefix_len=meta.prefix_len, meta=meta)
        group = inst.pre_batcher.add(work, t)
        if group is None and not inst.queue:
            group = inst.pre_batcher.take_for(work)
        if group is not None:
            self._start_pre_batch(t, inst, group)
            self._ensure_pre_flush(t, inst)
        else:
            inst.release_slot(t)
            if inst.pre_batcher.depth_for(work) == 1:
                self.schedule(t + inst.pre_batcher.cfg.max_wait_ms / 1e3,
                              "pre_flush", inst=inst)

    def _ensure_pre_flush(self, t: float, inst: InstanceRuntime) -> None:
        if inst.pre_batcher.pending:
            self.schedule(t + inst.pre_batcher.cfg.max_wait_ms / 1e3,
                          "pre_flush", inst=inst)

    def _on_pre_flush(self, t: float, inst: InstanceRuntime) -> None:
        for group in inst.pre_batcher.expired(t):
            inst.enqueue({"kind": "pre_batch", "group": group}, t)
        self._ensure_pre_flush(t, inst)

    def _on_pre_drain(self, t: float, inst: InstanceRuntime) -> None:
        while inst.free_slots > 0 and not inst.queue:
            group = inst.pre_batcher.take_oldest()
            if group is None:
                return
            inst.enqueue({"kind": "pre_batch", "group": group}, t)

    def _start_pre_batch(self, t: float, inst: InstanceRuntime,
                         group: List[PendingRank]) -> None:
        metas = [w.meta for w in group]
        inst.stats["pre_infers"] += len(metas)
        outs, ms = inst.executor.pre_infer_group(metas)
        inst.busy_ms += ms
        self.schedule(t + ms / 1e3, "pre_group_done", inst=inst,
                      group=group, outs=outs)

    def _on_pre_group_done(self, t: float, inst: InstanceRuntime,
                           group: List[PendingRank], outs) -> None:
        outbound: Dict[Optional[str], list] = {}
        for w, (psi, nbytes) in zip(group, outs):
            inst.inflight_pre.discard(w.user_id)
            if inst.role == "prefill":
                # batched disaggregated prefill: members of the one
                # jitted launch bound for the same rank host coalesce
                # into one NIC transfer (per-destination, below)
                if psi is not None:
                    target = self.router.route_key(w.user_id)
                    outbound.setdefault(
                        self.topology.host_of(target), []).append(
                        (target, w.meta, psi, nbytes))
                else:
                    self._ship_close(w.user_id)
                continue
            if self._ship_inflight.get(w.user_id):
                self._ship_close(w.user_id)
            target = self._misplaced(inst, w.user_id)
            if target is not None:
                self._forward_pre(t, inst, w.meta, psi, nbytes, target)
            else:
                inst.complete_pre(w.meta, psi, nbytes, t)
                self._settle_raced(inst, w.user_id)
        for dst_host, members in outbound.items():
            self._ship_group(t, inst, dst_host, members)
        inst.release_slot(t)
        for w in group:
            self._wake_waiters(t, inst, w.user_id)

    def _park(self, t: float, inst: InstanceRuntime, uid: int, job: dict
              ) -> None:
        job["t_park"] = t
        job.pop("t_enqueue", None)
        inst.user_waiters[uid].append(job)
        inst.release_slot(t)

    def _finish_rank(self, t: float, inst: InstanceRuntime, job: dict,
                     action: str, entry) -> None:
        if inst.batcher is not None:
            self._batch_rank(t, inst, job, action, entry)
            return
        rec: Record = job["rec"]
        comp = {"pre": rec.pre_ms, "load": rec.load_ms, "rank": 0.0,
                "queue": rec.queue_ms}
        result = inst.exec_rank(job["req"], action, entry, comp, t)
        rec.rank_ms = comp["rank"]
        rec.hit = result.hit.value
        if result.hit != HitKind.MISS_FALLBACK:
            rec.reused_tokens = _reused_tokens(entry)
        self.schedule(t + comp["rank"] / 1e3, "rank_done", inst=inst,
                      job=job, result=result)

    # --- continuous micro-batching (batched executor) -------------------------

    def _batch_rank(self, t: float, inst: InstanceRuntime, job: dict,
                    action: str, entry) -> None:
        """Rank step under batching: classify the hit, snapshot psi, park
        the request in the aggregator and give the model slot back — a
        group launch will re-acquire ONE slot for the whole batch."""
        req: Request = job["req"]
        rec: Record = job["rec"]
        meta = req.user
        hit, psi = inst.classify_rank(meta.user_id, action, entry,
                                      rec.load_ms)
        job["hit"] = hit
        if hit != HitKind.MISS_FALLBACK:
            rec.reused_tokens = _reused_tokens(entry)
        work = PendingRank(user_id=meta.user_id, psi=psi,
                           prefix_len=meta.prefix_len, meta=meta,
                           payload=job)
        group = inst.batcher.add(work, t)
        if group is None and not inst.queue:
            # continuous batching: we still hold a model slot and nothing
            # else is waiting for it — delaying for co-batchable arrivals
            # buys nothing, so launch immediately with whatever has
            # accumulated.  Batches deeper than one therefore only form
            # while slots are contended, which is exactly when they pay.
            group = inst.batcher.take_for(work)
        if group is not None:
            # reuse the slot this rank job already holds for the launch
            self._start_batch(t, inst, group)
            self._ensure_flush(t, inst)
        else:
            # contended: give the slot to the queued work and park; the
            # flush deadline bounds how long the group can accumulate
            inst.release_slot(t)
            if inst.batcher.depth_for(work) == 1:
                # one timer per queue head is enough: expired() keys off
                # the oldest member, and every take re-arms via
                # _ensure_flush for whatever it leaves behind
                self.schedule(t + inst.batcher.cfg.max_wait_ms / 1e3,
                              "batch_flush", inst=inst)

    def _launch_batch(self, t: float, inst: InstanceRuntime,
                      group: List[PendingRank]) -> None:
        inst.enqueue({"kind": "batch", "group": group}, t)

    def _ensure_flush(self, t: float, inst: InstanceRuntime) -> None:
        """Re-arm the flush deadline for whatever is still parked (e.g.
        overflow a full-batch take left queued without its own timer)."""
        if inst.batcher.pending:
            self.schedule(t + inst.batcher.cfg.max_wait_ms / 1e3,
                          "batch_flush", inst=inst)

    def _on_batch_flush(self, t: float, inst: InstanceRuntime) -> None:
        for group in inst.batcher.expired(t):
            self._launch_batch(t, inst, group)
        self._ensure_flush(t, inst)

    def _on_batch_drain(self, t: float, inst: InstanceRuntime) -> None:
        # drain as many pending groups as there are idle slots, so no
        # group waits out the flush deadline beside an unused slot
        while inst.free_slots > 0 and not inst.queue:
            group = inst.batcher.take_oldest()
            if group is None:
                return
            self._launch_batch(t, inst, group)

    def _start_batch(self, t: float, inst: InstanceRuntime,
                     group: List[PendingRank]) -> None:
        """Slot acquired: execute the group as one launch.  Aggregator +
        slot wait is per-request queueing; the group wall time is every
        member's rank component (they all ride the same call), keeping
        latency_ms == sum(components) == rank-stage wall time."""
        for w in group:
            w.payload["rec"].queue_ms += (t - w.enqueued_at) * 1e3
        scores, group_ms = inst.executor.rank_group(group)
        for w in group:
            inst.hbm.release_value(w.psi)  # unpin pages held since classify
        inst.busy_ms += group_ms
        results = []
        for w, s in zip(group, scores):
            job = w.payload
            rec: Record = job["rec"]
            comp = {"pre": rec.pre_ms, "load": rec.load_ms,
                    "rank": group_ms, "queue": rec.queue_ms}
            rec.rank_ms = group_ms
            rec.hit = job["hit"].value
            results.append(RankResult(
                req_id=job["req"].req_id, user_id=w.user_id,
                hit=job["hit"], scores=s, latency_ms=sum(comp.values()),
                components=comp, instance=inst.name))
        self.schedule(t + group_ms / 1e3, "batch_done", inst=inst,
                      group=group, results=results)

    def _on_batch_done(self, t: float, inst: InstanceRuntime,
                       group: List[PendingRank],
                       results: List[RankResult]) -> None:
        for w, result in zip(group, results):
            rec: Record = w.payload["rec"]
            e = inst.hbm.consume(result.user_id)
            if e is not None and inst.expander.cfg.dram_budget_bytes > 0:
                if inst.expander.spill(dataclasses.replace(e)):
                    inst.stats["spills"] += 1
                    e.dram_backed = True   # eligible for partial eviction
            rec.t_done = t
            rec.rank_stage_ms = rec.queue_ms + rec.load_ms + rec.rank_ms
            self.records.append(rec)
            self.slo.observe(now=t, e2e_ms=rec.e2e_ms, hit=rec.hit,
                             components=result.components)
            sink = w.payload.get("sink")
            if sink is not None:
                sink(result)
        inst.release_slot(t)

    # --- completions -------------------------------------------------------------

    def _misplaced(self, inst: InstanceRuntime, uid: int) -> Optional[str]:
        """After membership churn, an in-flight producer can complete on
        an instance that no longer owns its user (the pre-infer raced
        the rebalance).  Returns the owning target when the completion
        is misplaced; None on the hot path (no churn has ever happened
        or the placement is still correct)."""
        if self.cfg.cluster.rebalance != "handoff":
            return None
        if self.topology.epoch == 0 and self.instances.get(inst.name) is inst:
            return None
        target = self.router.route_key(uid)
        return None if target == inst.name else target

    def _forward_pre(self, t: float, inst: InstanceRuntime, meta: UserMeta,
                     psi: Any, nbytes: int, target: str) -> None:
        """Hand a freshly computed psi to the user's new owner instead
        of inserting it at the stale producer (prevents double
        ownership during the rebalance window)."""
        cross = (self.topology.host_of(target)
                 != self.topology.host_of(inst.name))
        arrival, ms = self._transfer(t, self.topology.host_of(inst.name),
                                     target, int(nbytes),
                                     meta.prefix_len or 1, cross)
        self.migration["entries"] += 1
        self.migration["cross_host" if cross else "intra_host"] += 1
        self.migration["ms"] += ms
        from .cache import CacheEntry
        spans = (reuse_spans(meta) if self.cfg.cluster.segments else None)
        entry = CacheEntry(meta.user_id, psi, int(nbytes), t,
                           prefix_len=meta.prefix_len, spans=spans,
                           tenant=meta.tenant)
        self.schedule(arrival, "handoff_done", target=target,
                      entry=entry, tier="hbm")

    def _on_pre_done(self, t: float, inst: InstanceRuntime, meta: UserMeta,
                     psi: Any, nbytes: int) -> None:
        uid = meta.user_id
        inst.inflight_pre.discard(uid)
        if inst.role == "prefill":
            # disaggregated side path: the engine never keeps psi — it
            # ships to the owning rank host (the shipment keeps the
            # user's in-flight marker open until it lands or drops)
            if psi is not None:
                self._ship_psi(t, inst, meta, psi, nbytes)
            else:
                self._ship_close(uid)
            inst.release_slot(t)
            return
        if self._ship_inflight.get(uid):
            # churn re-dispatched a disagg pre job onto a rank host:
            # psi completes locally, nothing is in the network anymore
            self._ship_close(uid)
        target = self._misplaced(inst, uid) if psi is not None else None
        if target is not None:
            self._forward_pre(t, inst, meta, psi, nbytes, target)
        else:
            inst.complete_pre(meta, psi, nbytes, t)
            self._settle_raced(inst, uid)
        inst.release_slot(t)
        self._wake_waiters(t, inst, uid)

    # --- cross-host psi shipping (disaggregated prefill) ----------------------

    def _ship_psi(self, t: float, inst: InstanceRuntime, meta: UserMeta,
                  psi: Any, nbytes: int) -> None:
        """Relay a freshly prefilled psi from its producing prefill
        engine to the user's owning rank instance: one cross-host hop
        on the NIC fabric (contending with concurrent shipments and
        rebalance migrations), landing as a ``ship_done`` insert."""
        target = self.router.route_key(meta.user_id)
        nb = int(nbytes) or self.cost.kv_bytes(meta.prefix_len or 1)
        arrival, ms = self._link_transfer(
            t, self.topology.host_of(inst.name),
            self.topology.host_of(target), nb, meta.prefix_len or 1)
        self.shipping["shipped"] += 1
        self.shipping["transfers"] += 1
        self.shipping["bytes"] += nb
        self.shipping["ms"] += ms
        self.schedule(arrival, "ship_done", target=target, meta=meta,
                      psi=psi, nbytes=nbytes)

    def _ship_group(self, t: float, inst: InstanceRuntime,
                    dst_host: Optional[str], members: list) -> None:
        """Coalesced shipment: every member of one batched prefill
        launch bound for the same rank host rides ONE NIC transfer —
        summed payload bytes, one serialization window, one RTT —
        through the same ``psi_transfer_ms``/``_link_transfer`` pricing
        as a solo shipment.  Each member still lands as its own
        ``ship_done`` (its target instance may differ within the
        host), so the late-miss race and churn forwarding are
        untouched."""
        total = 0
        len_sum = 0
        for _, meta, _, nbytes in members:
            total += int(nbytes) or self.cost.kv_bytes(meta.prefix_len or 1)
            len_sum += meta.prefix_len or 1
        arrival, ms = self._link_transfer(
            t, self.topology.host_of(inst.name), dst_host, total, len_sum)
        self.shipping["shipped"] += len(members)
        self.shipping["transfers"] += 1
        self.shipping["coalesced"] += len(members) - 1
        self.shipping["bytes"] += total
        self.shipping["ms"] += ms
        for target, meta, psi, nbytes in members:
            self.schedule(arrival, "ship_done", target=target, meta=meta,
                          psi=psi, nbytes=nbytes)

    def _on_ship_done(self, t: float, target: str, meta: UserMeta,
                      psi: Any, nbytes: int, hops: int = 0) -> None:
        uid = meta.user_id
        inst = self.instances.get(target)
        try:
            owner = self.router.route_key(uid)
        except Exception:
            owner = None
        if inst is None or (owner is not None and owner != target):
            # ownership churned while the psi was on the wire: forward
            # one more fabric hop to the new owner (bounded — continued
            # churn eventually drops the copy, which is safe: the rank
            # path falls back, it never double-owns)
            if hops >= 2 or owner is None or owner not in self.instances:
                self._ship_close(uid)
                self._settle_raced(None, uid)
                self.shipping["dropped"] += 1
                return
            nb = int(nbytes) or self.cost.kv_bytes(meta.prefix_len or 1)
            arrival, ms = self._link_transfer(
                t, self.topology.host_of(target),
                self.topology.host_of(owner), nb, meta.prefix_len or 1)
            self.shipping["forwarded"] += 1
            self.shipping["transfers"] += 1
            self.shipping["ms"] += ms
            self.schedule(arrival, "ship_done", target=owner, meta=meta,
                          psi=psi, nbytes=nbytes, hops=hops + 1)
            return
        self._ship_close(uid)
        self.shipping["landed"] += 1
        inst = self._adopt(inst)
        inst.complete_pre(meta, psi, nbytes, t)
        self._settle_raced(inst, uid)
        self._wake_waiters(t, inst, uid)

    def _settle_raced(self, inst: Optional[InstanceRuntime],
                      uid: int) -> None:
        """The rank this psi was produced for already fell back: the
        lifecycle is over, so a landed copy is consumed-on-arrival — it
        serves FUTURE requests (and exits the window through the spill
        path, never as a premature eviction)."""
        if uid in self._ship_raced and not self._ship_inflight.get(uid):
            self._ship_raced.discard(uid)
            if inst is not None:
                inst.hbm.consume(uid)
        if uid in self._promote_raced \
                and not self._promote_inflight.get(uid):
            # same contract for a promotion the rank outran: the
            # revived copy arrives consumed (and un-marks itself — the
            # lifecycle it was promoted for already missed)
            self._promote_raced.discard(uid)
            if inst is not None:
                e = inst.hbm.consume(uid)
                if e is not None:
                    e.cold_sourced = False

    def _on_pre_reload_done(self, t: float, inst: InstanceRuntime,
                            meta: UserMeta, ms: float,
                            slotless: bool = False) -> None:
        uid = meta.user_id
        inst.inflight_pre.discard(uid)
        if self._ship_inflight.get(uid):
            # churn re-routed a disagg pre job onto its rank owner and
            # a local DRAM reload satisfied it: nothing is on the wire
            # anymore, so the shipment marker must close here too
            self._ship_close(uid)
        inst.pcie_release(t)
        inst.expander.complete_reload(uid, inst.hbm, t)
        self._settle_raced(inst, uid)
        if self._misplaced(inst, uid) is not None:
            # the reload raced a rebalance: the promoted psi belongs to
            # the new owner now — hand it off instead of keeping it
            self._handoff_hbm(inst, uid, t)
        if not slotless:
            # a cold promotion released its model slot at the disk
            # read; only the slot-holding DRAM pre-reload returns one
            inst.release_slot(t)
        self._wake_waiters(t, inst, uid)

    def _on_reload_done(self, t: float, inst: InstanceRuntime, job: dict,
                        ms: float) -> None:
        req: Request = job["req"]
        uid = req.user.user_id
        job["rec"].load_ms = ms
        inst.pcie_release(t)
        action, entry = inst.apply_reload(uid, t)
        inst.expander.finish(uid)
        self._finish_rank(t, inst, job, action, entry)
        self._wake_waiters(t, inst, uid)

    def _wake_waiters(self, t: float, inst: InstanceRuntime, uid: int
                      ) -> None:
        for job in inst.user_waiters.pop(uid, []):
            # the parked interval is the pre-infer contribution to this
            # request's critical path (Fig. 11c attribution)
            job["rec"].pre_ms += (t - job.pop("t_park")) * 1e3
            inst.enqueue(job, t)

    def _on_rank_done(self, t: float, inst: InstanceRuntime, job: dict,
                      result: RankResult) -> None:
        rec: Record = job["rec"]
        e = inst.hbm.consume(result.user_id)
        if e is not None and inst.expander.cfg.dram_budget_bytes > 0:
            # proactive spill copy for short-term cross-request reuse
            if inst.expander.spill(dataclasses.replace(e)):
                inst.stats["spills"] += 1
                e.dram_backed = True       # eligible for partial eviction
        rec.t_done = t
        rec.rank_stage_ms = rec.queue_ms + rec.load_ms + rec.rank_ms
        self.records.append(rec)
        self.slo.observe(now=t, e2e_ms=rec.e2e_ms, hit=rec.hit,
                         components=result.components)
        sink = job.get("sink")
        if sink is not None:
            sink(result)
        inst.release_slot(t)

    # --- metrics -------------------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {"n": 0}
        pp = self.cfg.pipeline
        e2e = np.array([r.e2e_ms for r in self.records])
        rank_stage = np.array([r.rank_stage_ms for r in self.records])
        ok = e2e <= pp.pipeline_slo_ms
        dur = (max(r.t_done for r in self.records)
               - min(r.t_arrival for r in self.records))
        hits = defaultdict(int)
        for r in self.records:
            hits[r.hit] += 1
        n = len(self.records)
        out = {
            "n": n,
            "p50_ms": float(np.percentile(e2e, 50)),
            "p99_ms": float(np.percentile(e2e, 99)),
            "rank_p99_ms": float(np.percentile(rank_stage, 99)),
            "success_rate": float(ok.mean()),
            "throughput_qps": n / max(dur, 1e-9),
            "goodput_qps": int(ok.sum()) / max(dur, 1e-9),
            "hbm_hit": hits[HitKind.HBM_HIT.value] / n,
            "dram_hit": hits[HitKind.DRAM_HIT.value] / n,
            "cold_hit": hits[HitKind.COLD_HIT.value] / n,
            "miss": hits[HitKind.MISS_FALLBACK.value] / n,
            "pre_p99_ms": float(np.percentile(
                [r.pre_ms for r in self.records], 99)),
            "load_p99_ms": float(np.percentile(
                [r.load_ms for r in self.records], 99)),
            "rank_ms_p99": float(np.percentile(
                [r.rank_ms for r in self.records], 99)),
            "special_util": self._util(self.special, dur),
            "normal_util": self._util(self.normal, dur),
            # beyond-prefix reuse: fraction of all context tokens served
            # from cache (prefix-only paths reuse at most the prefix;
            # segment reuse adds the interior spans on every hit)
            "reused_frac": (sum(r.reused_tokens for r in self.records)
                            / max(sum(r.ctx_tokens for r in self.records),
                                  1)),
        }
        if self.prefill:
            # disaggregated deployments report the side-path hosts too:
            # the tentpole claim is that prefill compute leaves the
            # ranking hosts' slots (special_util drops, prefill_util
            # carries the pre-infer load)
            out["prefill_util"] = self._util(self.prefill, dur)
        return out

    def tenant_summary(self) -> Dict[int, Dict[str, float]]:
        """Per-tenant slice of ``summary()``: latency percentiles and
        hit-kind mix over each tenant's own records.  The isolation
        bench compares a tenant's slice across runs (solo vs a
        co-tenant bursting) — its hit rate and knee must not move."""
        by: Dict[int, List[Record]] = defaultdict(list)
        for r in self.records:
            by[r.tenant].append(r)
        out: Dict[int, Dict[str, float]] = {}
        pp = self.cfg.pipeline
        for t, recs in sorted(by.items()):
            n = len(recs)
            e2e = np.array([r.e2e_ms for r in recs])
            ok = e2e <= pp.pipeline_slo_ms
            hits = defaultdict(int)
            for r in recs:
                hits[r.hit] += 1
            miss = hits[HitKind.MISS_FALLBACK.value] / n
            out[t] = {
                "n": n,
                "p50_ms": float(np.percentile(e2e, 50)),
                "p99_ms": float(np.percentile(e2e, 99)),
                "success_rate": float(ok.mean()),
                "hbm_hit": hits[HitKind.HBM_HIT.value] / n,
                "dram_hit": hits[HitKind.DRAM_HIT.value] / n,
                "cold_hit": hits[HitKind.COLD_HIT.value] / n,
                "miss": miss,
                "hit_rate": 1.0 - miss,
            }
        return out

    def _util(self, names, dur) -> float:
        if not names or dur <= 0:
            return 0.0
        busy = sum(self.instances[n].busy_ms for n in names
                   if n in self.instances) / 1e3
        # per-instance slot counts: the prefill tier may be provisioned
        # with a different concurrency than the rank tier
        slots = sum(self.instances[n].cfg.m_slots if n in self.instances
                    else self.cfg.cluster.m_slots for n in names)
        return busy / (dur * slots) if slots else 0.0

    def stats(self) -> Dict[str, Dict]:
        agg = {"trigger": dict(self.trigger.stats),
               "router": dict(self.router.stats),
               "topology": {
                   "epoch": self.topology.epoch,
                   "converged": self.topology.converged(),
                   "hosts": {n: {"special": list(h.special),
                                 "normal": list(h.normal),
                                 "prefill": list(h.prefill),
                                 "role": h.role}
                             for n, h in self.topology.hosts.items()}},
               "migration": dict(self.migration),
               "shipping": {**self.shipping,
                            "inflight": sum(self._ship_inflight.values())},
               "nic": {h: dict(n) for h, n in self.nics.items()},
               # cold tier: the runtime ledger plus every store's
               # unified counter family (inserts/live/evictions/
               # handoffs/promotions); departed hosts' orphaned
               # namespaces report until their last entry re-homes
               "cold": {**self.cold,
                        "inflight": sum(self._promote_inflight.values()),
                        "stores": {
                            **{h: {**s.stats, "live": s.live_count}
                               for h, s in self.cold_stores.items()},
                            **{f"{h} (departed)": {**s.stats,
                                                   "live": s.live_count}
                               for h, s in self._orphan_cold.items()}}},
               "cold_links": {h: dict(l)
                              for h, l in self.cold_links.items()},
               "slo": self.slo.summary(now=self.now)}
        # host->device traffic ledger, summed over the paged windows:
        # scatter-on-insert bytes vs whole-pool launch re-ships.  On
        # the device-pool path ``launch_reships`` MUST read 0 and
        # ``bytes_scattered`` equals the freshly inserted page bytes
        # (the acceptance surface of the device-resident pool).
        h2d = {"bytes_scattered": 0, "pages_scattered": 0, "scatters": 0,
               "launch_reships": 0, "reshipped_bytes": 0}
        device_resident = False
        inst = {}
        for name, i in self.instances.items():
            # every tier reports the same counter core (inserts / live /
            # evictions / handoffs + tier extras) so this renders as
            # one coherent hierarchy table
            inst[name] = {**i.stats,
                          "hbm": {**i.hbm.stats,
                                  "live": i.hbm.live_count},
                          "dram": {**i.expander.stats,
                                   "live": len(i.expander.entries)}}
            if i.batcher is not None:
                inst[name]["batch"] = dict(i.batcher.stats)
            pool = getattr(i.hbm, "pool", None)
            if pool is not None:
                inst[name]["hbm"]["h2d"] = dict(pool.h2d)
                for k in h2d:
                    h2d[k] += pool.h2d[k]
                device_resident |= isinstance(pool, DevicePagePool)
        agg["h2d"] = {**h2d, "device_resident": device_resident}
        agg["instances"] = inst
        if self.tenants > 1:
            agg["tenants"] = self._tenant_rollup()
        return agg

    def _tenant_rollup(self) -> Dict[str, Dict]:
        """Fleet-wide per-tenant ledgers: the trigger's admission
        counters plus every tier's tenant_stats summed over stores.
        ``cross_tenant_evictions`` totals the partition-invariant
        violations across ALL tiers — 0 by construction."""
        def merge(dst: Dict[int, Dict[str, int]], src) -> None:
            if not src:
                return
            for t, d in src.items():
                row = dst.setdefault(int(t), {})
                for k, v in d.items():
                    row[k] = row.get(k, 0) + v

        hbm: Dict[int, Dict[str, int]] = {}
        dram: Dict[int, Dict[str, int]] = {}
        cold: Dict[int, Dict[str, int]] = {}
        cross = 0
        seen: set = set()
        for i in self.instances.values():
            merge(hbm, getattr(i.hbm, "tenant_stats", None))
            cross += i.hbm.stats.get("cross_tenant_evictions", 0)
            if id(i.expander) in seen:
                continue          # hosts share one expander tier
            seen.add(id(i.expander))
            merge(dram, getattr(i.expander, "tenant_stats", None))
            cross += i.expander.stats.get("cross_tenant_evictions", 0)
        for s in list(self.cold_stores.values()) \
                + list(self._orphan_cold.values()):
            merge(cold, getattr(s, "tenant_stats", None))
            cross += s.stats.get("cross_tenant_evictions", 0)
        admission = {int(t): dict(d) for t, d in
                     getattr(self.trigger, "tenant_stats", {}).items()}
        return {"admission": admission, "hbm": hbm, "dram": dram,
                "cold": cold, "cross_tenant_evictions": cross}
