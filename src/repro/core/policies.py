"""Pluggable policy registries for the relay-race runtime.

Serving-system papers (xGR, MTServe, ...) compare scheduling / admission
/ placement policies against one engine.  To reproduce such comparisons
the runtime resolves its three policy slots by name:

  * **trigger** — who gets a pre-infer signal (admission);
  * **router**  — where producer and consumer rendezvous (placement);
  * **expander** — what happens to psi after the HBM window (reuse tier).

Built-ins:

  trigger:  ``sequence-aware`` (paper Eqs. 1-3), ``admit-all``
            (unconditional pre-inference — the paper's §2.4 strawman),
            ``never`` (baseline: relay disabled at the admission level).
            Under disaggregated prefill (``ClusterConfig.prefill_hosts
            > 0``) ``make_trigger`` installs a shipping-cost estimator
            so the slack test prices the cross-host psi hop into
            admission.
  router:   ``affinity`` (consistent hashing on the user key, paper
            §3.3), ``random`` (placement ablation: producer/consumer
            miss each other).
  expander: ``dram`` (server-local DRAM reuse tier, paper §3.4).

Registering a policy is one decorator; selection is one string in
``ClusterConfig`` — scenario configs never import policy classes.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

from .costmodel import GRCostModel
from .expander import DRAMExpander, ExpanderConfig
from .router import AffinityRouter, _h
from .topology import ClusterTopology
from .trigger import Decision, SequenceAwareTrigger, TriggerConfig
from .types import HASH_KEY, UserMeta

TRIGGER_POLICIES: Dict[str, Callable] = {}
ROUTER_POLICIES: Dict[str, Callable] = {}
EXPANDER_POLICIES: Dict[str, Callable] = {}


def _register(registry: Dict[str, Callable], name: str):
    def deco(obj):
        registry[name] = obj
        return obj

    return deco


def register_trigger(name: str):
    return _register(TRIGGER_POLICIES, name)


def register_router(name: str):
    return _register(ROUTER_POLICIES, name)


def register_expander(name: str):
    return _register(EXPANDER_POLICIES, name)


def _get(registry: Dict[str, Callable], kind: str, name: str) -> Callable:
    try:
        return registry[name]
    except KeyError:
        raise KeyError(f"unknown {kind} policy {name!r}; "
                       f"registered: {sorted(registry)}") from None


def make_trigger(name: str, cfg: TriggerConfig, cost: GRCostModel,
                 ship_ms=None):
    """Build a trigger policy.  ``ship_ms`` (an optional
    ``UserMeta -> ms`` estimator) is installed as the trigger's
    ``ship_estimator`` — the disaggregated-prefill runtime passes the
    cross-host psi shipping cost so the slack test prices the full
    side-path deadline (compute + shipment), not just the compute: a
    psi that lands after its rank request is useless, so admission
    must account for the hop."""
    trigger = _get(TRIGGER_POLICIES, "trigger", name)(cfg, cost)
    if ship_ms is not None:
        trigger.ship_estimator = ship_ms
    return trigger


def make_router(name: str, special: List[str], normal: List[str], *,
                seed: int = 0,
                topology: Optional[ClusterTopology] = None):
    return _get(ROUTER_POLICIES, "router", name)(special, normal, seed=seed,
                                                 topology=topology)


def make_expander(name: str, cfg: ExpanderConfig, tenant_quota=None):
    cls = _get(EXPANDER_POLICIES, "expander", name)
    if tenant_quota is not None:
        return cls(cfg, tenant_quota=tenant_quota)
    return cls(cfg)


def policy_names() -> Dict[str, List[str]]:
    return {"trigger": sorted(TRIGGER_POLICIES),
            "router": sorted(ROUTER_POLICIES),
            "expander": sorted(EXPANDER_POLICIES)}


# --- built-in triggers ---------------------------------------------------------

register_trigger("sequence-aware")(SequenceAwareTrigger)


@register_trigger("admit-all")
class AdmitAllTrigger(SequenceAwareTrigger):
    """Unconditional pre-inference (paper §2.4, challenge 3): every
    request gets the side-path signal, flooding the special pool with
    work for safe short-sequence users.  ``assess`` keeps the real risk
    test so routing decisions stay meaningful."""

    def admit(self, meta: UserMeta, instance: str, now: float) -> Decision:
        d = self.assess(meta)
        self.stats["admitted"] += 1
        # the REAL risk verdict rides along: rank-stage routing keys off
        # Decision.at_risk, and the ablation only floods admission —
        # hard-coding True here would silently turn every short-sequence
        # request into keyed special-pool traffic as well
        return Decision(True, d.at_risk, d.est_full_ms, "admit-all")


@register_trigger("never")
class NeverTrigger(SequenceAwareTrigger):
    """Admission-level baseline: no request ever pre-infers (the risk
    assessment still runs so long-sequence routing is unchanged)."""

    def admit(self, meta: UserMeta, instance: str, now: float) -> Decision:
        d = self.assess(meta)
        return Decision(False, d.at_risk, d.est_full_ms, "never-admit")


# --- built-in routers ---------------------------------------------------------


@register_router("affinity")
def _affinity_router(special: List[str], normal: List[str], *, seed: int = 0,
                     topology: Optional[ClusterTopology] = None
                     ) -> AffinityRouter:
    # user_hash on the normal pool = session affinity for unkeyed
    # traffic (the behaviour the cluster benchmarks are calibrated to)
    return AffinityRouter(special, normal, policy="user_hash",
                          topology=topology)


@register_router("affinity-rr")
def _affinity_rr_router(special: List[str], normal: List[str], *,
                        seed: int = 0,
                        topology: Optional[ClusterTopology] = None
                        ) -> AffinityRouter:
    return AffinityRouter(special, normal, policy="round_robin",
                          topology=topology)


@register_router("random")
class RandomSpecialRouter(AffinityRouter):
    """Placement ablation (paper Fig. 12 argument): keyed requests go to
    a *random* special instance, so the pre-infer producer and the
    ranking consumer rendezvous only by chance and ranking mostly falls
    back to full inference.

    Placement is a pure hash of (seed, stage, key) — NOT a stateful RNG
    re-rolled per call — so two processes replaying the same stream
    (or the live and sim adapters in a parity sweep) pick identical
    "random" instances, while the pre-infer and rank stages of one user
    still hash independently and rendezvous only with probability
    1/n_special."""

    def __init__(self, special: List[str], normal: List[str], *,
                 seed: int = 0,
                 topology: Optional[ClusterTopology] = None, **kw):
        # same normal-pool policy as "affinity" so the ablation varies
        # ONLY the special-pool placement
        kw.setdefault("policy", "user_hash")
        super().__init__(special, normal, topology=topology, **kw)
        self._seed = int(seed)

    def route(self, request) -> str:
        key = request.header.get(HASH_KEY)
        if key is not None:
            # the live topology, not a construction-time snapshot: host
            # churn must never leave departed instances routable
            specials = self.topology.all_special()
            if not specials:
                # churn emptied the special pool: degrade to the
                # normal-pool path (AffinityRouter's discipline) — there
                # is nobody left to rendezvous at, which must mean a
                # fallback rank, never a crash on the empty modulus
                return self.route_normal(request)
            self.stats["special"] += 1
            hv = _h(f"random:{self._seed}:{request.stage.value}:{key}")
            return specials[hv % len(specials)]
        return super().route(request)


# --- built-in expanders ---------------------------------------------------------

register_expander("dram")(DRAMExpander)


@register_expander("null")
class NullExpander(DRAMExpander):
    """No DRAM reuse tier: psi lives only in the HBM window (equivalent
    to a zero DRAM budget, kept as an explicit policy for ablations)."""

    def __init__(self, cfg: ExpanderConfig, tenant_quota=None):
        super().__init__(ExpanderConfig(
            dram_budget_bytes=0.0,
            max_reload_concurrency=cfg.max_reload_concurrency))

    def spill(self, entry) -> bool:
        return False
