"""Executor protocol + registry: how a ranking instance computes.

The relay-race state machine never touches tensors directly — every
compute step goes through an ``Executor``:

  * ``SimExecutor``  — analytic cost-model latencies, no real compute
    (cluster-scale simulation, capacity planning, paper figures);
  * ``LiveExecutor`` — jitted JAX HSTU prefill / rank-with-cache /
    full-rank on the local device, latencies measured.

Both satisfy the same ``typing.Protocol``, so the runtime drives the
identical state machine in either mode; new backends register under a
name and are selected per deployment via ``get_executor``:

  * ``BatchedLiveExecutor`` (name ``batched``) — ``LiveExecutor`` plus
    continuous micro-batching: compatible rank requests grouped by the
    per-instance ``BatchAggregator`` execute as ONE jitted call on
    bucketed shapes (``rank_group``), and per-request shapes snap to
    the same bucket grid so batched and per-request scores agree
    bit-for-bit (tests/test_batching.py).

An executor opts into runtime-driven batching by carrying a
``batching: BatchingConfig`` attribute and a ``rank_group(group)``
method; ``RelayRuntime`` then parks rank work in a ``BatchAggregator``
and flushes groups through one model slot each.  ``SimExecutor``
mirrors the same surface via ``GRCostModel.batched_rank_ms`` so the
cluster simulator stays trace-comparable with the live engine.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Protocol, \
    Sequence, Tuple, runtime_checkable

import numpy as np

from repro.serving.batching import (BatchingConfig, PendingRank, bucket_of,
                                    pad_psi, stack_psi)

from .cache import kv_nbytes
from .costmodel import GRCostModel
from .types import UserMeta


@runtime_checkable
class Executor(Protocol):
    """Compute backend for one ranking instance."""

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        """Pre-infer psi for the user's long-term prefix.
        Returns (psi, nbytes, latency_ms)."""
        ...

    def rank_cached(self, meta: UserMeta, psi: Any) -> Tuple[Any, float]:
        """Rank candidates reusing cached psi. Returns (scores, ms)."""
        ...

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        """Full inference on the critical path (miss fallback)."""
        ...

    def reload_ms(self, meta: UserMeta) -> float:
        """DRAM -> HBM reload cost for this user's psi."""
        ...


# --- registry ----------------------------------------------------------------

EXECUTORS: Dict[str, Callable[..., Executor]] = {}


def register_executor(name: str):
    def deco(cls):
        EXECUTORS[name] = cls
        return cls

    return deco


def get_executor(name: str) -> Callable[..., Executor]:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown executor {name!r}; "
                       f"registered: {sorted(EXECUTORS)}") from None


def executor_names():
    return sorted(EXECUTORS)


# --- built-in executors --------------------------------------------------------


@register_executor("sim")
class SimExecutor:
    """Latency-only executor driven by the analytic cost model.

    Passing a ``BatchingConfig`` opts the executor into runtime-driven
    micro-batching: group launch cost comes from
    ``GRCostModel.batched_rank_ms`` — the sim-side mirror of the live
    ``batched`` executor, keeping ``ClusterSim`` trace-comparable."""

    def __init__(self, cost: GRCostModel,
                 batching: Optional[BatchingConfig] = None):
        self.cost = cost
        self.batching = batching

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        nbytes = self.cost.kv_bytes(meta.prefix_len)
        ms = self.cost.pre_infer_ms(meta.prefix_len)
        return ("psi", meta.user_id, meta.prefix_len), nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        return None, self.cost.rank_on_cache_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        return None, self.cost.full_rank_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)

    def rank_group(self, group: Sequence[PendingRank]
                   ) -> Tuple[List[Any], float]:
        """Rank a compatible group in one modelled launch.
        Returns (per-member scores, group wall ms)."""
        per = []
        for w in group:
            m = w.meta
            plen = m.prefix_len if m is not None else w.prefix_len
            if w.psi is not None:
                per.append(self.cost.rank_on_cache_ms(
                    plen, w.incr_len, w.n_items))
            else:
                per.append(self.cost.full_rank_ms(
                    plen, w.incr_len, w.n_items))
        return [None] * len(group), self.cost.batched_rank_ms(per)


@register_executor("live")
class LiveExecutor:
    """Runs the real HSTU backbone with jitted prefill / rank steps."""

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self.store = store
        self.cost = cost or GRCostModel(model.cfg)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}))
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))
        self._rank_full = jax.jit(
            lambda p, pref, incr, items: model.full_rank(
                p, pref, incr, items))

    def _round(self, n: int, m: int = 64) -> int:
        return max(m, (n + m - 1) // m * m)  # bucketed shapes: few recompiles

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        toks = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        t0 = time.perf_counter()
        _, kv = self._prefill(self.params, toks)
        kv = self._jax.block_until_ready(kv)
        ms = (time.perf_counter() - t0) * 1e3
        return kv, kv_nbytes(kv), ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank(self.params, psi, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        n = self._full_pad(meta.prefix_len)
        pref = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def _full_pad(self, n: int) -> int:
        """Padded prefix length for the full-inference fallback."""
        return self._round(n)

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)


@register_executor("batched")
class BatchedLiveExecutor(LiveExecutor):
    """LiveExecutor + continuous micro-batching on bucketed shapes.

    Shape discipline is what makes batching correct AND cheap:

      * pre-inference keeps the 64-token grid (psi stays compact);
      * every rank launch — per-request or grouped — snaps the prefix
        axis to the shared ``BUCKETS`` grid (psi zero-padded, which is
        exact for HSTU's silu attention; full-rank prefix tokens tiled,
        matching what the per-request call does after bucketing), so
        batched scores equal per-request scores bit-for-bit;
      * the batch axis snaps to a power-of-two grid by repeating the
        first member (row-independent compute, sliced off afterwards),
        bounding the jit cache to #buckets x log2(max_batch) entries —
        all pre-compiled by ``warmup`` so compiles leave the P99 path.
    """

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None,
                 batching: Optional[BatchingConfig] = None):
        super().__init__(model, params, store, cost)
        self.batching = batching or BatchingConfig()
        self._warmed: set = set()

    # --- per-request paths on the bucket grid -------------------------------

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        psi = pad_psi(self._jax.numpy, psi, bucket_of(psi[0].shape[2]))
        return super().rank_cached(meta, psi)

    def _full_pad(self, n: int) -> int:
        return bucket_of(n)

    # --- group path ---------------------------------------------------------

    def _batch_grid(self, n: int) -> int:
        """Smallest power-of-two >= n, clamped to max_batch (so a
        non-power-of-two max_batch tops the grid itself)."""
        b = 1
        while b < n and b < self.batching.max_batch:
            b *= 2
        return min(b, self.batching.max_batch)

    def rank_group(self, group: Sequence[PendingRank]
                   ) -> Tuple[List[Any], float]:
        """Execute a compatible group as ONE jitted call.
        Returns (per-member scores, measured group wall ms)."""
        jnp = self._jax.numpy
        n = len(group)
        bucket = bucket_of(max(w.prefix_len for w in group))
        pad_rows = self._batch_grid(n) - n
        rows = list(group) + [group[0]] * pad_rows
        incr = np.stack([w.incr if w.incr is not None
                         else self.store.short_term(w.user_id)
                         for w in rows])
        items = np.stack([w.items if w.items is not None
                          else self.store.candidates(w.user_id)
                          for w in rows])
        t0 = time.perf_counter()
        incr, items = jnp.asarray(incr), jnp.asarray(items)
        if group[0].psi is not None:          # homogeneous by aggregator key
            kv = stack_psi(jnp, [w.psi for w in rows], bucket)
            scores = self._rank(self.params, kv, incr, items)
        else:
            pref = jnp.asarray(np.stack([
                np.resize(self.store.long_term(w.user_id), bucket)
                for w in rows]))
            scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        return [scores[i] for i in range(n)], ms

    # --- startup pre-warming -------------------------------------------------

    def warmup(self, prefix_lens: Sequence[int],
               batch_sizes: Sequence[int] = (1,),
               incr_len: int = 64, n_items: int = 512) -> List[Tuple]:
        """Compile the bucketed rank entry points ahead of traffic.

        ``prefix_lens`` is the expected workload (e.g. the sampled
        arrival stream); the jit-cache guard keeps the
        ``batching.max_buckets_live`` *most frequent* buckets, so the
        traffic-dominant shapes are the warm ones — any dropped bucket
        still compiles lazily on first hit.  Returns the freshly
        compiled (bucket, batch) keys (already-warm keys are skipped).
        """
        from collections import Counter
        jax, jnp = self._jax, self._jax.numpy
        cfg = self.model.cfg
        freq = Counter(bucket_of(int(n)) for n in prefix_lens)
        buckets = sorted(b for b, _ in
                         freq.most_common(self.batching.max_buckets_live))
        sizes = sorted({self._batch_grid(int(b)) for b in batch_sizes})
        done = []
        for bucket in buckets:
            for nb in sizes:
                key = (bucket, nb, incr_len, n_items)
                if key in self._warmed:
                    continue
                z = jnp.zeros(
                    (cfg.n_layers, nb, bucket, cfg.n_heads, cfg.head_dim),
                    jnp.dtype(cfg.dtype))
                incr = jnp.zeros((nb, incr_len), jnp.int32)
                items = jnp.zeros((nb, n_items), jnp.int32)
                jax.block_until_ready(
                    self._rank(self.params, (z, z), incr, items))
                pref = jnp.zeros((nb, bucket), jnp.int32)
                jax.block_until_ready(
                    self._rank_full(self.params, pref, incr, items))
                self._warmed.add(key)
                done.append(key)
        return done
