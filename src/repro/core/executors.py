"""Executor protocol + registry: how a ranking instance computes.

The relay-race state machine never touches tensors directly — every
compute step goes through an ``Executor``:

  * ``SimExecutor``  — analytic cost-model latencies, no real compute
    (cluster-scale simulation, capacity planning, paper figures);
  * ``LiveExecutor`` — jitted JAX HSTU prefill / rank-with-cache /
    full-rank on the local device, latencies measured.

Both satisfy the same ``typing.Protocol``, so the runtime drives the
identical state machine in either mode; new backends (e.g. a batched
executor, a remote-NPU stub) register under a name and are selected per
deployment via ``get_executor``.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np

from .costmodel import GRCostModel
from .types import UserMeta


@runtime_checkable
class Executor(Protocol):
    """Compute backend for one ranking instance."""

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        """Pre-infer psi for the user's long-term prefix.
        Returns (psi, nbytes, latency_ms)."""
        ...

    def rank_cached(self, meta: UserMeta, psi: Any) -> Tuple[Any, float]:
        """Rank candidates reusing cached psi. Returns (scores, ms)."""
        ...

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        """Full inference on the critical path (miss fallback)."""
        ...

    def reload_ms(self, meta: UserMeta) -> float:
        """DRAM -> HBM reload cost for this user's psi."""
        ...


# --- registry ----------------------------------------------------------------

EXECUTORS: Dict[str, Callable[..., Executor]] = {}


def register_executor(name: str):
    def deco(cls):
        EXECUTORS[name] = cls
        return cls

    return deco


def get_executor(name: str) -> Callable[..., Executor]:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown executor {name!r}; "
                       f"registered: {sorted(EXECUTORS)}") from None


def executor_names():
    return sorted(EXECUTORS)


# --- built-in executors --------------------------------------------------------


@register_executor("sim")
class SimExecutor:
    """Latency-only executor driven by the analytic cost model."""

    def __init__(self, cost: GRCostModel):
        self.cost = cost

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        nbytes = self.cost.kv_bytes(meta.prefix_len)
        ms = self.cost.pre_infer_ms(meta.prefix_len)
        return ("psi", meta.user_id, meta.prefix_len), nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        return None, self.cost.rank_on_cache_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        return None, self.cost.full_rank_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)


@register_executor("live")
class LiveExecutor:
    """Runs the real HSTU backbone with jitted prefill / rank steps."""

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self.store = store
        self.cost = cost or GRCostModel(model.cfg)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}))
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))
        self._rank_full = jax.jit(
            lambda p, pref, incr, items: model.full_rank(
                p, pref, incr, items))

    def _round(self, n: int, m: int = 64) -> int:
        return max(m, (n + m - 1) // m * m)  # bucketed shapes: few recompiles

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        toks = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        t0 = time.perf_counter()
        _, kv = self._prefill(self.params, toks)
        kv = self._jax.block_until_ready(kv)
        ms = (time.perf_counter() - t0) * 1e3
        nbytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                     for a in self._jax.tree.leaves(kv))
        return kv, nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank(self.params, psi, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        pref = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)
