"""Executor protocol + registry: how a ranking instance computes.

The relay-race state machine never touches tensors directly — every
compute step goes through an ``Executor``:

  * ``SimExecutor``  — analytic cost-model latencies, no real compute
    (cluster-scale simulation, capacity planning, paper figures);
  * ``LiveExecutor`` — jitted JAX HSTU prefill / rank-with-cache /
    full-rank on the local device, latencies measured.

Both satisfy the same ``typing.Protocol``, so the runtime drives the
identical state machine in either mode; new backends register under a
name and are selected per deployment via ``get_executor``:

  * ``BatchedLiveExecutor`` (name ``batched``) — ``LiveExecutor`` plus
    continuous micro-batching: compatible rank requests grouped by the
    per-instance ``BatchAggregator`` execute as ONE jitted call on
    bucketed shapes (``rank_group``), and per-request shapes snap to
    the same bucket grid so batched and per-request scores agree
    bit-for-bit (tests/test_batching.py).

An executor opts into runtime-driven batching by carrying a
``batching: BatchingConfig`` attribute and a ``rank_group(group)``
method; ``RelayRuntime`` then parks rank work in a ``BatchAggregator``
and flushes groups through one model slot each.  ``SimExecutor``
mirrors the same surface via ``GRCostModel.batched_rank_ms`` so the
cluster simulator stays trace-comparable with the live engine.

Both executors also serve the *disaggregated-prefill* split
(``ClusterConfig.prefill_hosts > 0``): a dedicated prefill engine
drives only the side-path surface — ``pre_infer`` and the batched
``pre_infer_group`` — while its produced psi is shipped cross-host by
the runtime; the rank surface of the same executor runs on the owning
rank instances.  No prefill-specific executor subclass exists on
purpose: the compute is identical, only the placement (and the NIC
hop) differs.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Protocol, \
    Sequence, Tuple, runtime_checkable

import numpy as np

from repro.serving.batching import (BatchingConfig, PendingRank, bucket_of,
                                    pad_psi, prefill_grid, stack_psi)

from .cache import kv_nbytes
from .costmodel import GRCostModel
from .paging import DevicePagePool, PageLayout, PagedPsi, ceil_div
from .types import UserMeta


@runtime_checkable
class Executor(Protocol):
    """Compute backend for one ranking instance."""

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        """Pre-infer psi for the user's long-term prefix.
        Returns (psi, nbytes, latency_ms)."""
        ...

    def rank_cached(self, meta: UserMeta, psi: Any) -> Tuple[Any, float]:
        """Rank candidates reusing cached psi. Returns (scores, ms)."""
        ...

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        """Full inference on the critical path (miss fallback)."""
        ...

    def reload_ms(self, meta: UserMeta, tokens: Optional[int] = None
                  ) -> float:
        """DRAM -> HBM reload cost for this user's psi.  ``tokens``
        narrows the transfer to the missing suffix (paged stores resume
        partial reloads); None means the whole prefix."""
        ...


# --- paged psi launch helpers -------------------------------------------------


def page_bucket(tokens: int, page_tokens: int) -> int:
    """Page count a launch pads its tables to: the shared ``BUCKETS``
    token grid expressed in pages — THE first key component of the
    paged ``rank_with_pages`` jit cache (page-count bucket, batch)."""
    return ceil_div(bucket_of(int(tokens)), int(page_tokens))


def _pages_of(tokens: int, psi: PagedPsi) -> int:
    return page_bucket(tokens, psi.layout.page_tokens)


def _page_launch_args(jnp, psis: Sequence[PagedPsi], np_bucket: int):
    """Stack per-member page tables — (slabs, n) int32 — into the
    (B, L, 2, np_bucket) launch table, padding with the pool's null
    (all-zero) page so padded tokens contribute silu(0) = 0 exactly,
    matching the dense bucketed path's zero-padded psi.

    The pool buffer: a ``DevicePagePool`` passes its device-resident
    array by REFERENCE (zero host->device traffic per launch); a
    host-buffer pool re-ships the whole pool, counted in the owning
    pool's ``h2d`` ledger.  A member whose table exceeds ``np_bucket``
    is an error — truncating would silently drop cached pages from the
    gather (callers widen the launch bucket to the group's largest
    member instead)."""
    buf = psis[0].buffer
    null = buf.shape[0] - 1
    rows = []
    for psi in psis:
        slabs, n = psi.table.shape
        if n > np_bucket:
            raise ValueError(
                f"page table has {n} pages/slab but the launch bucket "
                f"is {np_bucket}: truncation would silently drop cached "
                f"pages — widen the bucket to the group's largest member")
        t = np.full((slabs, np_bucket), null, np.int32)
        t[:, :n] = psi.table
        rows.append(t.reshape(slabs // 2, 2, np_bucket))
    pool = psis[0].pool
    if isinstance(pool, DevicePagePool):
        launch_buf = pool.device_view(buf)
    else:
        launch_buf = jnp.asarray(buf)      # O(pool bytes) per launch
        if pool is not None:
            pool.h2d["launch_reships"] += 1
            pool.h2d["reshipped_bytes"] += int(buf.nbytes)
    return launch_buf, jnp.asarray(np.stack(rows))


def _gather_psi(jnp, buf, tables):
    """Inside-jit gather: pool buffer (N + 1, pt, H, D) + launch tables
    (B, L, 2, np) -> the (K, V) pytree of stacked (L, B, np * pt, H, D)
    that ``rank_with_cache`` consumes.  On TPU the Pallas kernel
    (``repro.kernels.paged_prefix_attn``) reads the pool through the
    page-table BlockSpec index map instead."""
    g = jnp.take(buf, tables, axis=0)      # (B, L, 2, np, pt, H, D)
    B, L, _, npg, pt, H, D = g.shape
    g = g.reshape(B, L, 2, npg * pt, H, D)
    k = jnp.transpose(g[:, :, 0], (1, 0, 2, 3, 4))
    v = jnp.transpose(g[:, :, 1], (1, 0, 2, 3, 4))
    return (k, v)


# --- registry ----------------------------------------------------------------

EXECUTORS: Dict[str, Callable[..., Executor]] = {}


def register_executor(name: str):
    def deco(cls):
        EXECUTORS[name] = cls
        return cls

    return deco


def get_executor(name: str) -> Callable[..., Executor]:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown executor {name!r}; "
                       f"registered: {sorted(EXECUTORS)}") from None


def executor_names():
    return sorted(EXECUTORS)


# --- built-in executors --------------------------------------------------------


@register_executor("sim")
class SimExecutor:
    """Latency-only executor driven by the analytic cost model.

    Passing a ``BatchingConfig`` opts the executor into runtime-driven
    micro-batching: group launch cost comes from
    ``GRCostModel.batched_rank_ms`` — the sim-side mirror of the live
    ``batched`` executor, keeping ``ClusterSim`` trace-comparable."""

    def __init__(self, cost: GRCostModel,
                 batching: Optional[BatchingConfig] = None,
                 page_tokens: int = 0, segments: bool = False):
        self.cost = cost
        self.batching = batching
        self.page_tokens = int(page_tokens)
        # beyond-prefix segment reuse: the side path also computes the
        # candidate-independent interior segments (UserMeta.seg_lens),
        # and a cache hit ranks only the truly fresh tokens.  Disabled
        # (or with empty seg_lens) every cost is unchanged.
        self.segments = bool(segments)

    def _seg_tokens(self, meta: UserMeta) -> int:
        if not self.segments:
            return 0
        return int(sum(getattr(meta, "seg_lens", ()) or ()))

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        reuse = meta.prefix_len + self._seg_tokens(meta)
        nbytes = self.cost.kv_bytes(reuse)
        ms = self.cost.pre_infer_ms(reuse)
        return ("psi", meta.user_id, reuse), nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        segs = self._seg_tokens(meta)
        return None, self.cost.rank_on_cache_ms(
            meta.prefix_len + segs, meta.incr_len - segs, meta.n_items)

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        return None, self.cost.full_rank_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def reload_ms(self, meta: UserMeta, tokens: Optional[int] = None
                  ) -> float:
        t = meta.prefix_len if tokens is None else tokens
        if self.page_tokens:
            # page-granular streaming: resumed reloads pay only for the
            # missing pages — the sim mirror of the paged live store
            return self.cost.paged_load_ms(t, self.page_tokens)
        return self.cost.dram_load_ms(t)

    def rank_group(self, group: Sequence[PendingRank]
                   ) -> Tuple[List[Any], float]:
        """Rank a compatible group in one modelled launch.
        Returns (per-member scores, group wall ms)."""
        per = []
        for w in group:
            m = w.meta
            plen = m.prefix_len if m is not None else w.prefix_len
            if w.psi is not None:
                segs = self._seg_tokens(m) if m is not None else 0
                per.append(self.cost.rank_on_cache_ms(
                    plen + segs, w.incr_len - segs, w.n_items))
            else:
                per.append(self.cost.full_rank_ms(
                    plen, w.incr_len, w.n_items))
        bucket = bucket_of(max(w.prefix_len for w in group))
        return ([None] * len(group),
                self.cost.batched_rank_ms(per, bucket=bucket))

    def pre_infer_group(self, metas: Sequence[UserMeta]
                        ) -> Tuple[List[Tuple[Any, int]], float]:
        """Pre-infer a prefill-grid-compatible group as one modelled
        launch (the batched side path).  Returns
        ([(psi, nbytes), ...], group wall ms) — single-member groups
        cost exactly the per-request ``pre_infer``, keeping uncontended
        traces bit-identical to the unbatched side path."""
        outs, per = [], []
        for m in metas:
            psi, nbytes, ms = self.pre_infer(m)
            outs.append((psi, nbytes))
            per.append(ms)
        bucket = prefill_grid(max(m.prefix_len for m in metas))
        return outs, self.cost.batched_rank_ms(per, bucket=bucket)


@register_executor("live")
class LiveExecutor:
    """Runs the real HSTU backbone with jitted prefill / rank steps."""

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None, page_tokens: int = 0,
                 segments: bool = False, device_pool: bool = False):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self.store = store
        self.cost = cost or GRCostModel(model.cfg)
        self.page_tokens = int(page_tokens)
        self.segments = bool(segments)
        # device-resident page pool: the serving window allocates a
        # DevicePagePool and routes page writes through the
        # insert_pages/free_pages hooks below, so rank_with_pages
        # launches pass the pool by reference instead of re-shipping
        # the host buffer (InstanceRuntime wires store <-> executor)
        self.device_pool = bool(device_pool) and self.page_tokens > 0
        # the executor owns compute geometry: a paged window must page
        # THIS model's psi, not the (possibly full-scale) cost model's
        self.page_layout = (PageLayout.from_model_config(
            model.cfg, page_tokens) if page_tokens else None)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}))
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))
        self._rank_full = jax.jit(
            lambda p, pref, incr, items: model.full_rank(
                p, pref, incr, items))
        # paged consumption: psi gathered from the page pool inside the
        # jitted launch (device-side gather; no host re-materialization)
        self._rank_pages = jax.jit(
            lambda p, buf, tables, incr, items: model.rank_with_cache(
                p, _gather_psi(self._jax.numpy, buf, tables), incr, items))

    def _round(self, n: int, m: int = 64) -> int:
        return max(m, (n + m - 1) // m * m)  # bucketed shapes: few recompiles

    def _pad_segments(self, kv, meta: UserMeta):
        """Append the segmented entry's span slots to live psi: one
        whole-page run of ZERO K/V per interior segment, matching the
        page grid ``PagedHBMStore.insert`` sizes a span-carrying entry
        to.  Zero keys are exact under silu attention (they contribute
        silu(0)·v = 0), so live scores equal the prefix-only launch
        while the span storage/gather machinery runs end-to-end; real
        interior-segment compute rides the Pallas segment kernel
        (``repro.kernels.paged_prefix_attn.segment_rank_attn``)."""
        segs = tuple(getattr(meta, "seg_lens", ()) or ())
        if not (self.segments and self.page_layout is not None and segs):
            return kv
        jnp = self._jax.numpy
        pt = self.page_layout.page_tokens
        extra = sum(pt * ceil_div(int(s), pt) for s in segs)

        def pad(a):
            z = jnp.zeros(a.shape[:2] + (extra,) + a.shape[3:], a.dtype)
            return jnp.concatenate([a, z], axis=2)

        return tuple(pad(a) for a in kv)

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        toks = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        t0 = time.perf_counter()
        _, kv = self._prefill(self.params, toks)
        kv = self._jax.block_until_ready(kv)
        ms = (time.perf_counter() - t0) * 1e3
        kv = self._pad_segments(kv, meta)
        return kv, kv_nbytes(kv), ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        if isinstance(psi, PagedPsi):
            buf, tables = _page_launch_args(jnp, [psi],
                                            _pages_of(psi.n_tokens, psi))
            scores = self._rank_pages(self.params, buf, tables, incr, items)
        else:
            scores = self._rank(self.params, psi, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        n = self._full_pad(meta.prefix_len)
        pref = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def _full_pad(self, n: int) -> int:
        """Padded prefix length for the full-inference fallback."""
        return self._round(n)

    def reload_ms(self, meta: UserMeta, tokens: Optional[int] = None
                  ) -> float:
        t = meta.prefix_len if tokens is None else tokens
        if self.page_tokens:
            return self.cost.paged_load_ms(t, self.page_tokens)
        return self.cost.dram_load_ms(t)

    # --- device-pool hooks ---------------------------------------------------
    # The paged window routes its page-data movement through the
    # executor (the owner of the jax device), so every path that writes
    # pages — fresh insert, resumed partial reload, handoff re-insert,
    # cold-promotion landing — lands them in the device-resident pool
    # with ONE donated scatter, and every free goes back through the
    # same conserved free-list accounting.

    def insert_pages(self, pool: DevicePagePool, pages: Sequence[int],
                     host_buffer: np.ndarray) -> int:
        """Scatter freshly written ``pages`` (already staged in the
        host buffer) into the device-resident pool.  Returns the bytes
        moved over the H2D link (== len(pages) * page_bytes)."""
        return pool.scatter(pages, host_buffer)

    def free_pages(self, pool, pages: Sequence[int]) -> None:
        """Return pages to the pool's free list (pin/zombie protection
        applies unchanged).  No device write: a freed page is
        unreachable until realloc re-stages and re-scatters it."""
        pool.free(pages)


@register_executor("batched")
class BatchedLiveExecutor(LiveExecutor):
    """LiveExecutor + continuous micro-batching on bucketed shapes.

    Shape discipline is what makes batching correct AND cheap:

      * pre-inference keeps the 64-token grid (psi stays compact);
      * every rank launch — per-request or grouped — snaps the prefix
        axis to the shared ``BUCKETS`` grid (psi zero-padded, which is
        exact for HSTU's silu attention; full-rank prefix tokens tiled,
        matching what the per-request call does after bucketing), so
        batched scores equal per-request scores bit-for-bit;
      * the batch axis snaps to a power-of-two grid by repeating the
        first member (row-independent compute, sliced off afterwards),
        bounding the jit cache to #buckets x log2(max_batch) entries —
        all pre-compiled by ``warmup`` so compiles leave the P99 path;
      * over a paged HBM window (``page_tokens > 0``) the group path
        becomes ``rank_with_pages``: members carry ``PagedPsi`` handles,
        their page tables pad to the page-count bucket with the pool's
        null page, and K/V are gathered from the pool INSIDE the one
        jitted launch — same (bucket, batch) key discipline, scores
        bit-identical to the dense path (tests/test_paging.py).
    """

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None,
                 batching: Optional[BatchingConfig] = None,
                 page_tokens: int = 0, segments: bool = False,
                 device_pool: bool = False):
        super().__init__(model, params, store, cost,
                         page_tokens=page_tokens, segments=segments,
                         device_pool=device_pool)
        self.batching = batching or BatchingConfig()
        self._warmed: set = set()

    # --- per-request paths on the bucket grid -------------------------------

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        if isinstance(psi, PagedPsi):
            # page tables already pad to the page-count bucket in super
            return super().rank_cached(meta, psi)
        psi = pad_psi(self._jax.numpy, psi, bucket_of(psi[0].shape[2]))
        return super().rank_cached(meta, psi)

    def _full_pad(self, n: int) -> int:
        return bucket_of(n)

    # --- group path ---------------------------------------------------------

    def _batch_grid(self, n: int) -> int:
        """Smallest power-of-two >= n, clamped to max_batch (so a
        non-power-of-two max_batch tops the grid itself)."""
        b = 1
        while b < n and b < self.batching.max_batch:
            b *= 2
        return min(b, self.batching.max_batch)

    def rank_group(self, group: Sequence[PendingRank]
                   ) -> Tuple[List[Any], float]:
        """Execute a compatible group as ONE jitted call.
        Returns (per-member scores, measured group wall ms)."""
        jnp = self._jax.numpy
        n = len(group)
        bucket = bucket_of(max(w.prefix_len for w in group))
        pad_rows = self._batch_grid(n) - n
        rows = list(group) + [group[0]] * pad_rows
        incr = np.stack([w.incr if w.incr is not None
                         else self.store.short_term(w.user_id)
                         for w in rows])
        items = np.stack([w.items if w.items is not None
                          else self.store.candidates(w.user_id)
                          for w in rows])
        t0 = time.perf_counter()
        incr, items = jnp.asarray(incr), jnp.asarray(items)
        if isinstance(group[0].psi, PagedPsi):
            # rank_with_pages: ONE launch keyed (page-count bucket,
            # batch grid); K/V stay in the page pool and are gathered
            # through the stacked page tables inside the jit.  The
            # bucket widens to the group's largest member: a segmented
            # entry's whole-page span padding can push its table past
            # the prefix-derived bucket, and truncating it would drop
            # cached pages from the gather (prefix-only members never
            # exceed the prefix bucket, so this is exact for them)
            pt = group[0].psi.layout.page_tokens
            npb = max([page_bucket(bucket, pt)]
                      + [_pages_of(w.psi.n_tokens, w.psi) for w in rows])
            buf, tables = _page_launch_args(jnp, [w.psi for w in rows], npb)
            scores = self._rank_pages(self.params, buf, tables, incr, items)
        elif group[0].psi is not None:        # homogeneous by aggregator key
            kv = stack_psi(jnp, [w.psi for w in rows], bucket)
            scores = self._rank(self.params, kv, incr, items)
        else:
            pref = jnp.asarray(np.stack([
                np.resize(self.store.long_term(w.user_id), bucket)
                for w in rows]))
            scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        return [scores[i] for i in range(n)], ms

    def pre_infer_group(self, metas: Sequence[UserMeta]
                        ) -> Tuple[List[Tuple[Any, int]], float]:
        """Batched pre-inference: ONE jitted prefill for a group sharing
        the 64-token prefill grid (the aggregator keys pre work by
        ``prefill_grid``, so every member's padded length is identical).
        The batch axis snaps to the power-of-two grid by repeating the
        first member, and each member's psi slice — rows are
        independent under batched compute — is bit-identical to the psi
        its own per-request ``pre_infer`` call would have produced."""
        jnp = self._jax.numpy
        n = self._round(max(m.prefix_len for m in metas))
        rows = list(metas)
        rows += [metas[0]] * (self._batch_grid(len(metas)) - len(metas))
        toks = np.stack([np.resize(self.store.long_term(m.user_id), n)
                         for m in rows])
        t0 = time.perf_counter()
        _, kv = self._prefill(self.params, jnp.asarray(toks))
        kv = self._jax.block_until_ready(kv)
        ms = (time.perf_counter() - t0) * 1e3
        outs = []
        for i in range(len(metas)):
            psi = tuple(a[:, i:i + 1] for a in kv)   # (L, 1, n, H, D)
            psi = self._pad_segments(psi, metas[i])
            outs.append((psi, kv_nbytes(psi)))
        return outs, ms

    # --- startup pre-warming -------------------------------------------------

    def warmup(self, prefix_lens: Sequence[int],
               batch_sizes: Sequence[int] = (1,),
               incr_len: int = 64, n_items: int = 512,
               pool_pages: int = 0) -> List[Tuple]:
        """Compile the bucketed rank entry points ahead of traffic.

        ``prefix_lens`` is the expected workload (e.g. the sampled
        arrival stream); the jit-cache guard keeps the
        ``batching.max_buckets_live`` *most frequent* buckets, so the
        traffic-dominant shapes are the warm ones — any dropped bucket
        still compiles lazily on first hit.  Returns the freshly
        compiled (bucket, batch) keys (already-warm keys are skipped).

        With ``page_tokens`` set, also pre-compiles the
        ``rank_with_pages`` entries keyed (page-count bucket, batch) —
        ``pool_pages`` must match the serving store's pool size (the
        pool buffer shape is part of the jit key)."""
        from collections import Counter
        jax, jnp = self._jax, self._jax.numpy
        cfg = self.model.cfg
        freq = Counter(bucket_of(int(n)) for n in prefix_lens)
        buckets = sorted(b for b, _ in
                         freq.most_common(self.batching.max_buckets_live))
        sizes = sorted({self._batch_grid(int(b)) for b in batch_sizes})
        done = []
        for bucket in buckets:
            for nb in sizes:
                key = (bucket, nb, incr_len, n_items)
                if key in self._warmed:
                    continue
                z = jnp.zeros(
                    (cfg.n_layers, nb, bucket, cfg.n_heads, cfg.head_dim),
                    jnp.dtype(cfg.dtype))
                incr = jnp.zeros((nb, incr_len), jnp.int32)
                items = jnp.zeros((nb, n_items), jnp.int32)
                jax.block_until_ready(
                    self._rank(self.params, (z, z), incr, items))
                pref = jnp.zeros((nb, bucket), jnp.int32)
                jax.block_until_ready(
                    self._rank_full(self.params, pref, incr, items))
                if self.page_tokens and pool_pages:
                    npb = page_bucket(bucket, self.page_tokens)
                    buf = jnp.zeros(
                        (pool_pages + 1, self.page_tokens,
                         cfg.n_heads, cfg.head_dim), jnp.dtype(cfg.dtype))
                    tables = jnp.zeros((nb, cfg.n_layers, 2, npb), jnp.int32)
                    jax.block_until_ready(self._rank_pages(
                        self.params, buf, tables, incr, items))
                self._warmed.add(key)
                done.append(key)
        return done
