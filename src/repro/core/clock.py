"""Clock protocol for the relay-race runtime.

The canonical lifecycle state machine (repro.core.runtime) is event-
driven; the only difference between live serving and cluster simulation
is which clock stamps and advances the timeline:

  * ``WallClock`` — live mode.  ``now()`` reads the host monotonic
    clock; ``advance()`` is a no-op because real time advances itself.
    Event timestamps come from request arrival times (caller-supplied
    or read off this clock) plus measured executor latencies.
  * ``VirtualClock`` — simulation mode.  Time is purely logical and the
    event loop advances it to each popped event's timestamp, so a
    12-second cluster trace replays in milliseconds of host time.

Anything satisfying the ``Clock`` protocol can drive the runtime (e.g.
a trace-replay clock that follows recorded production timestamps).
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    def now(self) -> float:
        """Current time in seconds (origin is clock-defined)."""
        ...

    def advance(self, t: float) -> None:
        """The event loop reached timestamp ``t``; logical clocks jump
        there, physical clocks ignore it."""
        ...


class WallClock:
    """Monotonic host clock anchored at construction (live mode)."""

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance(self, t: float) -> None:  # real time cannot be steered
        pass


class VirtualClock:
    """Discrete-event logical clock (simulation mode)."""

    def __init__(self, start: float = 0.0) -> None:
        self._now = start

    def now(self) -> float:
        return self._now

    def advance(self, t: float) -> None:
        self._now = t
