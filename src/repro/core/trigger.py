"""Sequence-aware trigger: selective admission of at-risk requests
(paper §3.2, Eqs. 1-3).

The trigger runs beside retrieval, inspects only lightweight behaviour
metadata, and admits a request for prefix pre-inference iff

  (risk)  full inline ranking would violate the ranking-stage P99 budget,
  (Eq. 2) the live caches it creates survive T_life under the HBM budget:
              L * kv_p99 <= r1 * HBM,   L = Q_admit * T_life       (Eq. 1)
  (Eq. 3) per-instance compute is not overloaded:
              Q_admit <= Q_m * M, and pool-wide
              Q_max   <= (Q_m * M) * (r2 * N).

Rates are enforced with token buckets (one per special instance plus a
pool-wide bucket), so admission is load-aware at millisecond granularity.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from .costmodel import GRCostModel
from .types import UserMeta


@dataclasses.dataclass(frozen=True)
class TriggerConfig:
    hbm_bytes: float = 32e9          # HBM per special instance
    r1: float = 0.5                  # HBM fraction reserved for live caches
    t_life_s: float = 0.3            # request lifecycle window
    q_m: float = 30.0                # pre-infer QPS per model slot
    m_slots: int = 5                 # concurrent model slots per instance
    r2: float = 0.1                  # fraction of instances that are special
    n_instances: int = 100           # total ranking instances
    rank_p99_budget_ms: float = 50.0 # ranking-stage P99 budget
    kv_p99_len: int = 4096           # P99 prefix length among admitted users
    concurrency_factor: float = 2.0  # queueing amplification at high QPS
    # beyond-paper (EXPERIMENTS.md §Perf): only admit when pre-inference
    # is estimated to finish inside the retrieval+preprocess slack, so
    # ranking never parks on its own pre-infer signal. 0 disables.
    # Under disaggregated prefill the runtime installs a shipping-cost
    # estimator (``SequenceAwareTrigger.ship_estimator``) and the slack
    # test prices the cross-host psi shipment too — a psi that arrives
    # after its rank request is useless, so it must not be admitted.
    slack_budget_ms: float = 0.0
    # multi-tenant serving: number of tenants sharing the fleet.  1
    # (default) builds no tenant machinery at all — bit-identical to
    # the single-tenant trigger.  With tenants > 1, admission layers a
    # per-tenant token bucket between the instance and pool buckets so
    # one tenant's surge cannot consume another tenant's admission
    # budget.
    tenants: int = 1
    # per-tenant share of the pool admission rate, indexed by tenant id
    # (tuple to stay hashable).  Empty -> equal shares.
    tenant_shares: tuple = ()
    # per-tenant SLO classes: (rank_p99_budget_ms, slack_budget_ms)
    # per tenant id.  A tenant beyond the tuple (or an empty tuple)
    # falls back to the global rank_p99_budget_ms / slack_budget_ms.
    tenant_slo: tuple = ()

    @property
    def n_special(self) -> int:
        return max(1, int(round(self.r2 * self.n_instances)))

    def tenant_rank_budget_ms(self, tenant: int) -> float:
        if 0 <= tenant < len(self.tenant_slo):
            return float(self.tenant_slo[tenant][0])
        return self.rank_p99_budget_ms

    def tenant_slack_ms(self, tenant: int) -> float:
        if 0 <= tenant < len(self.tenant_slo):
            return float(self.tenant_slo[tenant][1])
        return self.slack_budget_ms

    def tenant_share(self, tenant: int) -> float:
        if 0 <= tenant < len(self.tenant_shares):
            return float(self.tenant_shares[tenant])
        return 1.0 / max(self.tenants, 1)


class TokenBucket:
    """Leaky token bucket with a LAZY epoch.

    The bucket's clock starts at the first ``try_take`` — not at
    construction.  The old ``t_last = 0.0`` initialisation credited the
    whole wall-clock epoch (``now - 0``) as idle refill on the first
    take: harmless while the initial allowance equals ``burst`` (the
    cap masks it), but any bucket configured to start below ``burst``
    would be silently topped up to a full free burst the moment it was
    first consulted with a real timestamp.  Refill is also clamped to
    non-negative elapsed time so an out-of-order timestamp can never
    drain (or mint) tokens.
    """

    def __init__(self, rate: float, burst: Optional[float] = None,
                 tokens: Optional[float] = None):
        self.rate = float(rate)
        self.burst = burst if burst is not None else max(rate, 1.0)
        # initial allowance: a full bucket by default (deliberate — the
        # first T_life window may admit a burst), never above burst
        self.tokens = (self.burst if tokens is None
                       else min(float(tokens), self.burst))
        self.t_last: Optional[float] = None   # epoch set on first take

    def try_take(self, now: float) -> bool:
        if self.t_last is not None:
            elapsed = max(0.0, now - self.t_last)
            self.tokens = min(self.burst,
                              self.tokens + elapsed * self.rate)
            self.t_last = max(self.t_last, now)
        else:
            self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclasses.dataclass
class Decision:
    admitted: bool
    at_risk: bool
    est_full_ms: float
    reason: str


class SequenceAwareTrigger:
    def __init__(self, cfg: TriggerConfig, cost: GRCostModel):
        self.cfg = cfg
        self.cost = cost
        self.kv_p99_bytes = cost.kv_bytes(cfg.kv_p99_len)
        # Eq. 2 -> cap on live caches, Eq. 1 -> admitted rate cap
        self.live_cap = cfg.r1 * cfg.hbm_bytes / self.kv_p99_bytes
        rate_survive = self.live_cap / cfg.t_life_s
        rate_compute = cfg.q_m * cfg.m_slots                      # Eq. 3a
        self.q_admit = min(rate_survive, rate_compute)
        self.q_max = rate_compute * cfg.n_special                 # Eq. 3b
        self._instance_buckets: Dict[str, TokenBucket] = {}
        # per-instance admission-rate overrides (Eq. 3a with the
        # instance's TRUE compute): a dedicated prefill engine serves
        # the whole pool's side path, so its rate is q_m x its own
        # slot count, not the rank-instance default — the runtime
        # fills this for the prefill tier
        self.instance_rates: Dict[str, float] = {}
        self._pool_bucket = TokenBucket(self.q_max)
        # multi-tenant admission: one bucket per tenant, layered
        # between the instance and pool buckets (empty dict — and zero
        # overhead on the admit path — when tenants == 1)
        self._tenant_buckets: Dict[int, TokenBucket] = {}
        self.tenant_stats: Dict[int, Dict[str, int]] = {}
        if cfg.tenants > 1:
            for t in range(cfg.tenants):
                self._tenant_buckets[t] = TokenBucket(
                    self.q_max * cfg.tenant_share(t))
                self.tenant_stats[t] = {
                    "assessed": 0, "at_risk": 0, "admitted": 0,
                    "rate_limited": 0, "rate_limited_tenant": 0,
                    "rate_limited_instance": 0, "rate_limited_pool": 0,
                    "slack_rejected": 0}
        # disaggregated prefill: the runtime installs an estimate of the
        # cross-host psi shipping delay (ms as a function of UserMeta);
        # the slack test then admits only when pre-infer AND the
        # shipment both fit the retrieval/preprocess window.
        self.ship_estimator = None
        # hierarchical cold tier: the runtime installs a probe that
        # returns the promotion-path estimate (cold read + reload, ms)
        # for a cold-RESIDENT user, or None.  For those users the side
        # path is a revival, not a prefill — the slack test prices the
        # (much cheaper) disk path instead of the compute estimate, so
        # long-prefix tail users that prefill would price out of the
        # deadline stay admittable once their psi exists cold.
        self.cold_estimator = None
        # segment-aware value scoring (beyond-prefix reuse): when the
        # runtime flips this on, admission scores the TOTAL reusable
        # tokens (prefix + candidate-independent interior segments),
        # not just the prefix — the side path computes and caches every
        # reusable span, so the slack deadline must price all of them
        self.segments = False
        self.stats = {"assessed": 0, "at_risk": 0, "admitted": 0,
                      "rate_limited": 0, "rate_limited_pool": 0,
                      "rate_limited_instance": 0,
                      "rate_limited_tenant": 0, "slack_rejected": 0,
                      "cold_scored": 0, "reusable_tokens_admitted": 0}

    def _tbump(self, tenant: int, key: str) -> None:
        ts = self.tenant_stats.get(tenant)
        if ts is not None:
            ts[key] += 1

    # --- side-path risk test (metadata only) -------------------------------
    def assess(self, meta: UserMeta) -> Decision:
        self.stats["assessed"] += 1
        tenant = getattr(meta, "tenant", 0)
        self._tbump(tenant, "assessed")
        dim_scale = (meta.dim / self.cost.cfg.d_model) ** 2 \
            if meta.dim else 1.0
        est = self.cost.full_rank_ms(
            meta.prefix_len, meta.incr_len, meta.n_items,
            dim_scale=dim_scale) * self.cfg.concurrency_factor
        # per-tenant SLO class: each tenant is at-risk against ITS OWN
        # ranking budget (identical to the global budget when no
        # tenant_slo classes are configured)
        at_risk = est > self.cfg.tenant_rank_budget_ms(tenant)
        if at_risk:
            self.stats["at_risk"] += 1
            self._tbump(tenant, "at_risk")
        return Decision(False, at_risk, est,
                        "at-risk" if at_risk else "safe")

    # --- segment-aware value score (beyond-prefix reuse) ---------------------
    def reusable_tokens(self, meta: UserMeta) -> int:
        """Total cacheable tokens for this request: the prefix, plus —
        under segment reuse — every candidate-independent interior
        segment.  This is the value score admission prices: more
        reusable tokens means more rank-time saved per admitted psi."""
        toks = int(meta.prefix_len)
        if self.segments:
            toks += int(sum(getattr(meta, "seg_lens", ()) or ()))
        return toks

    # --- admission ----------------------------------------------------------
    def admit(self, meta: UserMeta, instance: str, now: float) -> Decision:
        d = self.assess(meta)
        tenant = getattr(meta, "tenant", 0)
        if not d.at_risk:
            return Decision(False, False, d.est_full_ms, "safe")
        reuse = self.reusable_tokens(meta)
        slack_ms = self.cfg.tenant_slack_ms(tenant)
        if slack_ms:
            cold_est = (self.cold_estimator(meta)
                        if self.cold_estimator is not None else None)
            if cold_est is not None:
                # cold-resident: the side path promotes the existing
                # psi (disk read + reload) instead of prefilling — no
                # compute, no shipping hop
                self.stats["cold_scored"] += 1
                pre_est = cold_est
            else:
                pre_est = self.cost.pre_infer_ms(reuse)
                if self.ship_estimator is not None:
                    # psi must land at the OWNER before ranking arrives:
                    # the shipping hop is on the relay's deadline path
                    pre_est += self.ship_estimator(meta)
            if pre_est > slack_ms:
                self.stats["slack_rejected"] += 1
                self._tbump(tenant, "slack_rejected")
                return Decision(False, True, d.est_full_ms,
                                "insufficient-slack")
        bucket = self._instance_buckets.get(instance)
        if bucket is None:
            bucket = TokenBucket(self.instance_rates.get(instance,
                                                         self.q_admit))
            self._instance_buckets[instance] = bucket
        # instance bucket first: an instance-rate rejection must not
        # burn a pool token (pool-wide under-admission under
        # per-instance contention); each later take refunds the earlier
        # tokens on ITS rejection for the same reason
        if not bucket.try_take(now):
            self.stats["rate_limited"] += 1
            self.stats["rate_limited_instance"] += 1
            self._tbump(tenant, "rate_limited")
            self._tbump(tenant, "rate_limited_instance")
            return Decision(False, True, d.est_full_ms,
                            "instance-rate-limited")
        # tenant bucket second (multi-tenant only): a tenant that has
        # exhausted its share is rejected HERE, before it can burn a
        # pool token another tenant is entitled to — the isolation
        # guarantee admission contributes
        tbucket = self._tenant_buckets.get(tenant)
        if tbucket is not None and not tbucket.try_take(now):
            bucket.tokens = min(bucket.burst, bucket.tokens + 1.0)
            self.stats["rate_limited"] += 1
            self.stats["rate_limited_tenant"] += 1
            self._tbump(tenant, "rate_limited")
            self._tbump(tenant, "rate_limited_tenant")
            return Decision(False, True, d.est_full_ms,
                            "tenant-rate-limited")
        if not self._pool_bucket.try_take(now):
            bucket.tokens = min(bucket.burst, bucket.tokens + 1.0)
            if tbucket is not None:
                tbucket.tokens = min(tbucket.burst, tbucket.tokens + 1.0)
            self.stats["rate_limited"] += 1
            self.stats["rate_limited_pool"] += 1
            self._tbump(tenant, "rate_limited")
            self._tbump(tenant, "rate_limited_pool")
            return Decision(False, True, d.est_full_ms, "pool-rate-limited")
        self.stats["admitted"] += 1
        self.stats["reusable_tokens_admitted"] += reuse
        self._tbump(tenant, "admitted")
        return Decision(True, True, d.est_full_ms, "admitted")

    # --- derived quantities (paper §3.2 sanity check) ------------------------
    def summary(self) -> Dict[str, float]:
        return {
            "kv_p99_bytes": self.kv_p99_bytes,
            "live_cache_cap_L": self.live_cap,
            "q_admit_per_instance": self.q_admit,
            "q_max_pool": self.q_max,
            "n_special": self.cfg.n_special,
        }
