"""Ranking-instance engine: where pre-inference and ranking execute.

A special instance processes a mix of auxiliary pre-infer requests and
ranking requests (paper Fig. 7).  The request-handling state machine is
identical in live mode (real JAX HSTU compute — tests, examples) and in
simulation mode (cost-model latencies — cluster-scale benchmarks); only
the ``Executor`` differs.

Latency components are reported per request as ``pre`` (pre-inference),
``load`` (DRAM->HBM reload), ``rank`` (ranking compute) — matching the
paper's Fig. 11c breakdown.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .cache import HBMCacheStore
from .costmodel import GRCostModel
from .expander import DRAMExpander, ExpanderConfig
from .types import HitKind, RankResult, Request, Stage, UserMeta


class SimExecutor:
    """Latency-only executor driven by the analytic cost model."""

    def __init__(self, cost: GRCostModel):
        self.cost = cost

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        nbytes = self.cost.kv_bytes(meta.prefix_len)
        ms = self.cost.pre_infer_ms(meta.prefix_len)
        return ("psi", meta.user_id, meta.prefix_len), nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        return None, self.cost.rank_on_cache_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        return None, self.cost.full_rank_ms(
            meta.prefix_len, meta.incr_len, meta.n_items)

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)


class LiveExecutor:
    """Runs the real HSTU backbone with jitted prefill / rank steps."""

    def __init__(self, model, params, store,
                 cost: Optional[GRCostModel] = None):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self.store = store
        self.cost = cost or GRCostModel(model.cfg)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}))
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))
        self._rank_full = jax.jit(
            lambda p, pref, incr, items: model.full_rank(
                p, pref, incr, items))

    def _round(self, n: int, m: int = 64) -> int:
        return max(m, (n + m - 1) // m * m)  # bucketed shapes: few recompiles

    def pre_infer(self, meta: UserMeta) -> Tuple[Any, int, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        toks = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        t0 = time.perf_counter()
        _, kv = self._prefill(self.params, toks)
        kv = self._jax.block_until_ready(kv)
        ms = (time.perf_counter() - t0) * 1e3
        nbytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                     for a in self._jax.tree.leaves(kv))
        return kv, nbytes, ms

    def rank_cached(self, meta: UserMeta, psi) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank(self.params, psi, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def rank_full(self, meta: UserMeta) -> Tuple[Any, float]:
        jnp = self._jax.numpy
        n = self._round(meta.prefix_len)
        pref = jnp.asarray(
            np.resize(self.store.long_term(meta.user_id), n)[None, :])
        incr = jnp.asarray(self.store.short_term(meta.user_id)[None, :])
        items = jnp.asarray(self.store.candidates(meta.user_id)[None, :])
        t0 = time.perf_counter()
        scores = self._rank_full(self.params, pref, incr, items)
        scores.block_until_ready()
        return scores, (time.perf_counter() - t0) * 1e3

    def reload_ms(self, meta: UserMeta) -> float:
        return self.cost.dram_load_ms(meta.prefix_len)


@dataclasses.dataclass
class InstanceConfig:
    name: str
    hbm_cache_bytes: float = 16e9       # r1 * HBM
    dram: ExpanderConfig = dataclasses.field(default_factory=ExpanderConfig)
    special: bool = True
    m_slots: int = 5


class RankingInstance:
    """One accelerator-backed ranking instance (normal or special)."""

    def __init__(self, cfg: InstanceConfig, executor):
        self.cfg = cfg
        self.name = cfg.name
        self.executor = executor
        self.hbm = HBMCacheStore(int(cfg.hbm_cache_bytes))
        self.expander = DRAMExpander(cfg.dram)
        self.stats = {"pre_infers": 0, "ranks": 0, "hbm_hits": 0,
                      "dram_hits": 0, "fallbacks": 0, "spills": 0}

    # --- pre-infer (relay-race side path) -----------------------------------
    def handle_pre_infer(self, req: Request, now: float) -> Dict[str, float]:
        meta = req.user
        self.stats["pre_infers"] += 1
        psi, nbytes, pre_ms = self.executor.pre_infer(meta)
        evicted = self.hbm.insert(meta.user_id, psi, nbytes, now,
                                  prefix_len=meta.prefix_len)
        for e in evicted:
            if e.consumed:  # sliding-window exit -> DRAM reuse tier
                self.expander.spill(e)
                self.stats["spills"] += 1
        return {"pre": pre_ms}

    # --- ranking -------------------------------------------------------------
    def handle_rank(self, req: Request, now: float) -> RankResult:
        meta = req.user
        self.stats["ranks"] += 1
        comp: Dict[str, float] = {"pre": 0.0, "load": 0.0, "rank": 0.0}

        action, entry = self.expander.pseudo_pre_infer(
            meta.user_id, self.hbm, now)
        single_flight_open = action in ("reload", "wait", "miss")

        if action == "wait":
            # Follower behind an in-flight op for the same user: the
            # leader's reload lands psi in HBM; re-probe (at most once).
            self.expander.finish(meta.user_id)
            e2 = self.hbm.lookup(meta.user_id)
            action, entry = ("hbm", e2) if e2 is not None else ("miss", None)
            single_flight_open = False

        if action == "reload":
            comp["load"] = self.executor.reload_ms(meta)
            self.expander.complete_reload(meta.user_id, self.hbm, now)
            entry = self.hbm.lookup(meta.user_id)
            action = "hbm" if entry is not None else "miss"

        if action == "hbm" and entry is not None:
            scores, rank_ms = self.executor.rank_cached(meta, entry.value)
            comp["rank"] = rank_ms
            self.hbm.consume(meta.user_id)
            hit = (HitKind.DRAM_HIT if comp["load"] > 0
                   else HitKind.HBM_HIT)
            self.stats["dram_hits" if comp["load"] > 0
                       else "hbm_hits"] += 1
        else:
            # I1: never a remote fetch — local miss falls back to full
            # inference, preserving correctness at the cost of latency.
            scores, rank_ms = self.executor.rank_full(meta)
            comp["rank"] = rank_ms
            hit = HitKind.MISS_FALLBACK
            self.stats["fallbacks"] += 1

        if single_flight_open:
            self.expander.finish(meta.user_id)

        return RankResult(
            req_id=req.req_id, user_id=meta.user_id, hit=hit, scores=scores,
            latency_ms=sum(comp.values()), components=comp,
            instance=self.name)
