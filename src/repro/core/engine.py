"""Compatibility layer for the pre-runtime engine API.

The ranking-instance state machine that used to live here is now the
single source of truth in ``repro.core.runtime`` (``InstanceRuntime``),
and the executors moved to ``repro.core.executors`` (protocol +
registry).  This module keeps the historical import surface working:

    from repro.core.engine import RankingInstance, SimExecutor, ...

``RankingInstance`` *is* ``InstanceRuntime`` — the same object the
event-driven runtime schedules — so manually-driven instances (tests,
ablations, churn experiments) and pipeline-driven ones share one
implementation.
"""

from __future__ import annotations

from .executors import (Executor, LiveExecutor, SimExecutor, get_executor,
                        register_executor)
from .runtime import InstanceConfig, InstanceRuntime

RankingInstance = InstanceRuntime

__all__ = ["Executor", "InstanceConfig", "InstanceRuntime", "LiveExecutor",
           "RankingInstance", "SimExecutor", "get_executor",
           "register_executor"]
