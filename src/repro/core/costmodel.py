"""Analytic latency cost model for GR serving on an accelerator instance.

Used by (a) the sequence-aware trigger's risk test, (b) the discrete-
event cluster simulator, and (c) the benchmark harness when deriving
paper-figure curves.  Constants default to a production-mirror Ascend
910C-class instance and are calibrated so that the absolute numbers in
the paper's evaluation are reproduced (pre-inference ~35 ms at ~3.5K
tokens for the HSTU backbone; rank-on-cache < 10 ms at 512 candidates;
DRAM->HBM load < 20 ms at 15K-token caches; remote fetch 100s of times
local access).  See EXPERIMENTS.md §Calibration.

All returned latencies are in milliseconds.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Sequence

from repro.models.config import ModelConfig

from .paging import ceil_div


def load_batch_calibration(path: str) -> Dict:
    """Load a measured batching-cost table written by
    ``benchmarks/calibrate.py``: per-(prefix-bucket, batch-depth)
    marginal-cost factors replacing the fixed ``batch_factor``.  Format:

        {"default": 0.2,
         "buckets": {"256": {"2": 0.18, "4": 0.21, "8": 0.24}, ...}}

    Keys are strings (JSON); values are the marginal cost of each
    non-dominant member as a fraction of the dominant member's solo
    latency.  A table written under ``--h2d`` additionally carries an
    ``"h2d"`` block — measured scatter-insert vs full-pool-reship
    bandwidths per (pool pages, inserted pages), consumed by
    ``GRCostModel.scatter_ms``.  Feed the result to
    ``GRCostModel.with_calibration``."""
    with open(path) as f:
        table = json.load(f)
    if "buckets" not in table:
        raise ValueError(f"{path}: not a batch-calibration table "
                         "(missing 'buckets')")
    return table


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    # effective sustained throughput for small-GR-model inference
    # (small matmuls at batch<=1k tokens reach ~<1% of peak cube FLOPs on
    # a 910C-class part; the default reproduces pre(2K) ~= 35 ms)
    eff_flops: float = 2.0e12          # FLOP/s sustained
    hbm_bw: float = 1.6e12             # B/s
    h2d_bw: float = 2.0e10             # B/s (PCIe/host-link, shared)
    net_bw: float = 1.25e9             # B/s cross-server (10 GbE share)
    net_rtt_ms: float = 2.0            # per remote fetch
    # Dedicated cross-host psi fabric (100 GbE-class share per host):
    # the provisioned background channel that rebalance migrations and
    # disaggregated-prefill psi shipping ride.  Distinct from net_bw —
    # invariant I1 forbids the *synchronous per-request* fetch over the
    # congested serving network; planned bulk transfers get the fat
    # link, and the runtime serializes concurrent transfers on each
    # host's link (NIC contention) rather than paying latency only.
    nic_bw: float = 1.25e10            # B/s per-host shipping fabric
    # Cold-tier store bandwidth (host-local NVMe SSD or a remote psi
    # store's per-host share): the third link class under the NIC.
    # Demotions (DRAM eviction -> cold) and promotions (cold -> DRAM
    # prefetch) serialize on each host's cold link exactly like
    # shipments serialize on its NIC — SSDs are not full duplex, so the
    # cold link is a single queue.  cold_rtt_ms models submission /
    # seek latency per I/O, analogous to net_rtt_ms per fabric hop.
    cold_bw: float = 6.0e9             # B/s host SSD / remote-store share
    cold_rtt_ms: float = 0.5           # per cold-store I/O
    host_feature_ms: float = 2.0       # CPU feature processing per request
    embed_bytes_per_token: int = 1024  # host->device embedding traffic


@dataclasses.dataclass(frozen=True)
class GRCostModel:
    cfg: ModelConfig
    hw: HardwareModel = HardwareModel()
    # Marginal cost of adding one request to a bucketed batched rank
    # launch, as a fraction of the dominant member's solo latency: small
    # GR matmuls leave most of the MXU idle, so co-scheduled requests
    # ride largely on the same pass (calibrated so an 8-deep batch costs
    # ~2.4x one request, mirroring the live ``batched`` executor).
    batch_factor: float = 0.2
    # Measured per-(bucket, batch) factor table from benchmarks/
    # calibrate.py (load_batch_calibration); None -> the fixed
    # batch_factor above.
    batch_calibration: Optional[Dict[str, Any]] = None

    def with_calibration(self, table) -> "GRCostModel":
        """Return a copy whose batched launch costs come from a measured
        table (``load_batch_calibration`` result or a path to one).
        A table with an ``"h2d"`` block also calibrates
        ``scatter_ms`` (measured host->device page-landing bandwidth)."""
        if isinstance(table, str):
            table = load_batch_calibration(table)
        return dataclasses.replace(self, batch_calibration=table)

    # ---- model primitives -------------------------------------------------
    def layer_param_flops(self) -> int:
        c = self.cfg
        if c.hstu:
            per = 4 * c.d_model * c.n_heads * c.head_dim \
                + c.n_heads * c.head_dim * c.d_model
        else:
            per = (2 * c.d_model * c.n_heads * c.head_dim
                   + 2 * c.d_model * c.n_kv_heads * c.head_dim
                   + 3 * c.d_model * c.d_ff)
        return 2 * per

    def forward_flops(self, n_tokens: int, n_ctx: int = None) -> float:
        """FLOPs for a forward pass of ``n_tokens`` attending to
        ``n_ctx`` context tokens (quadratic term)."""
        c = self.cfg
        n_ctx = n_ctx if n_ctx is not None else n_tokens
        lin = n_tokens * c.n_layers * self.layer_param_flops()
        attn = 4 * n_tokens * n_ctx * c.n_layers * c.n_heads * c.head_dim
        return lin + attn

    def kv_bytes(self, seq_len: int) -> int:
        c = self.cfg
        itemsize = 4 if c.dtype == "float32" else 2
        return 2 * c.n_layers * seq_len * c.n_heads * c.head_dim * itemsize

    # ---- serving-path latencies (ms) ---------------------------------------
    def h2d_ms(self, seq_len: int) -> float:
        bytes_ = seq_len * self.hw.embed_bytes_per_token
        return bytes_ / self.hw.h2d_bw * 1e3

    def pre_infer_ms(self, prefix_len: int, dim_scale: float = 1.0) -> float:
        """Pre-inference of the long-term prefix (relay-race side path)."""
        fl = self.forward_flops(prefix_len) * dim_scale
        return (fl / self.hw.eff_flops * 1e3
                + self.h2d_ms(prefix_len) + self.hw.host_feature_ms)

    def rank_on_cache_ms(self, prefix_len: int, incr_len: int,
                         n_items: int, dim_scale: float = 1.0) -> float:
        """Ranking that reuses cached psi: only incremental tokens +
        candidate items run, attending to the full context."""
        q = incr_len + n_items
        fl = self.forward_flops(q, n_ctx=prefix_len + q) * dim_scale
        return (fl / self.hw.eff_flops * 1e3
                + self.h2d_ms(q) + self.hw.host_feature_ms)

    def full_rank_ms(self, prefix_len: int, incr_len: int, n_items: int,
                     dim_scale: float = 1.0) -> float:
        """Baseline: the whole sequence on the ranking critical path."""
        n = prefix_len + incr_len + n_items
        fl = self.forward_flops(n) * dim_scale
        return (fl / self.hw.eff_flops * 1e3
                + self.h2d_ms(n) + self.hw.host_feature_ms)

    def _marginal_factor(self, bucket: Optional[int], n: int) -> float:
        """Per-member marginal batching cost: the measured table when
        one is loaded (nearest bucket at or above, deepest measured
        batch at or below), else the fixed ``batch_factor``."""
        cal = self.batch_calibration
        if cal is None or n <= 1:
            return self.batch_factor
        default = float(cal.get("default", self.batch_factor))
        buckets = cal.get("buckets") or {}
        if not buckets:
            return default
        keys = sorted(int(b) for b in buckets if buckets[b])
        if not keys:
            return default
        if bucket is None:
            bucket = keys[-1]
        b = next((k for k in keys if k >= int(bucket)), keys[-1])
        row = buckets[str(b)]
        depths = sorted(int(d) for d in row if int(d) <= n) or \
            [min(int(d) for d in row)]
        return float(row[str(depths[-1])])

    def batched_rank_ms(self, per_request_ms,
                        bucket: Optional[int] = None) -> float:
        """Wall time of one micro-batched launch whose members would
        individually cost ``per_request_ms`` — the sim-side mirror of the
        live ``batched`` executor (consumed by ``SimExecutor.rank_group``
        and ``pre_infer_group``).  Dominant member at full cost, the
        rest at the marginal factor (measured per (bucket, batch) when
        a calibration table is loaded, fixed ``batch_factor`` otherwise).
        """
        per = list(per_request_ms)
        if not per:
            return 0.0
        factor = self._marginal_factor(bucket, len(per))
        return max(per) * (1.0 + factor * (len(per) - 1))

    def dram_load_ms(self, prefix_len: int) -> float:
        """DRAM -> HBM reload of psi (expander hit) — one move on the
        unified ``"h2d"`` link class (``psi_transfer_ms``), so reloads
        and scatter-on-insert landings can never drift apart."""
        return self.psi_transfer_ms(prefix_len, link="h2d")

    def paged_load_ms(self, tokens: int, page_tokens: int) -> float:
        """DRAM -> HBM reload at page granularity: only the missing
        ``tokens`` move (a resumed partial reload passes the remainder,
        not the whole prefix), rounded up to whole pages — the
        last-page padding is the only over-transfer.  Same ``"h2d"``
        link class as ``dram_load_ms``."""
        if tokens <= 0:
            return 0.0
        pages = ceil_div(int(tokens), int(page_tokens))
        return self.psi_transfer_ms(pages * int(page_tokens), link="h2d")

    def scatter_ms(self, nbytes: int) -> float:
        """Host->device landing cost of freshly staged pool pages (the
        device pool's scatter-on-insert).  Uses the measured ``h2d``
        calibration (``benchmarks/calibrate.py --h2d`` via
        ``with_calibration``: effective scatter bandwidth including the
        per-call dispatch overhead) when loaded, else the raw
        ``hw.h2d_bw`` link class."""
        cal = (self.batch_calibration or {}).get("h2d") or {}
        bw = float(cal.get("scatter_bw", 0.0)) or self.hw.h2d_bw
        return max(int(nbytes), 0) / bw * 1e3

    def remote_fetch_ms(self, prefix_len: int) -> float:
        """Cross-server cache fetch — the path RelayGR's invariant I1
        forbids on the ranking critical path."""
        return (self.hw.net_rtt_ms
                + self.kv_bytes(prefix_len) / self.hw.net_bw * 1e3)

    # ---- off-critical-path psi transfers (NIC bandwidth model) -------------

    def link_occupancy_ms(self, nbytes: int, *, link: str = "nic") -> float:
        """Time one transfer *occupies* a host's link of the given
        bandwidth class — ``"nic"`` (shipping fabric), ``"cold"``
        (SSD / remote psi store) or ``"h2d"`` (the shared host->device
        link: DRAM->HBM reloads and scatter-on-insert page landings):
        the serialization term of a move.  The runtime's per-host link
        model charges this window against the involved links so
        concurrent shipments, migrations and cold-tier moves contend
        for bandwidth instead of overlapping for free; RTT is
        propagation and does not occupy the link."""
        bw = {"cold": self.hw.cold_bw,
              "h2d": self.hw.h2d_bw}.get(link, self.hw.nic_bw)
        return max(int(nbytes), 0) / bw * 1e3

    def psi_transfer_ms(self, prefix_len: int, *, cross_host: bool = True,
                        link: str = "nic") -> float:
        """THE pricing entry point for any psi that leaves its instance
        off the critical path — rebalance migrations (ownership
        handoff), disaggregated-prefill psi shipping, and cold-tier
        demotions/promotions all price through here, so the paths can
        never drift.  ``link="nic"`` (default): a cross-host move rides
        the dedicated shipping fabric (``hw.nic_bw`` + RTT); an
        intra-host move (ring change within one server) only re-crosses
        the local H2D/DRAM path.  ``link="cold"``: one cold-store I/O
        (DRAM <-> host SSD / remote store) — ``hw.cold_bw`` +
        submission latency; ``cross_host`` is ignored because a
        cross-host cold move composes this with a NIC leg.
        ``link="h2d"``: a host->device landing — DRAM->HBM reloads and
        the device pool's scatter-on-insert both ride the shared H2D
        link class (``hw.h2d_bw``), no fabric RTT; ``cross_host`` is
        ignored (the move is local by definition).  Never charged
        per-request: invariant I1 still forbids critical-path remote
        fetches (``remote_fetch_ms``)."""
        if link == "cold":
            return (self.hw.cold_rtt_ms
                    + self.link_occupancy_ms(self.kv_bytes(prefix_len),
                                             link="cold"))
        if link == "h2d" or not cross_host:
            return self.link_occupancy_ms(self.kv_bytes(prefix_len),
                                          link="h2d")
        return (self.hw.net_rtt_ms
                + self.link_occupancy_ms(self.kv_bytes(prefix_len)))

    def handoff_ms(self, prefix_len: int, cross_host: bool = True) -> float:
        """Back-compat alias: rebalance handoffs are priced by the
        unified ``psi_transfer_ms`` entry point."""
        return self.psi_transfer_ms(prefix_len, cross_host=cross_host)
