"""RelayGR core: lifecycle caching under late-binding placement.

The paper's contribution as a composable library: sequence-aware trigger
(admission, Eqs. 1-3), affinity-aware router (placement, invariant I1),
memory-aware expander (DRAM reuse tier), HBM sliding-window cache
(invariant I2) — all orchestrated by the single event-driven
``RelayRuntime`` (repro.core.runtime), which live serving
(``RelayGRService``) and the cluster simulator drive through pluggable
clocks, executors and policies.
"""
from repro.serving.batching import (BatchAggregator, BatchingConfig,
                                    PendingRank, bucket_of)

from .cache import (CacheEntry, HBMCacheStore, PagedHBMStore, kv_nbytes,
                    make_hbm_store)
from .paging import DevicePagePool, PageLayout, PagePool, PagedPsi
from .clock import Clock, VirtualClock, WallClock
from .coldstore import ColdStore, ColdStoreConfig
from .costmodel import GRCostModel, HardwareModel
from .engine import InstanceConfig, RankingInstance
from .executors import (EXECUTORS, BatchedLiveExecutor, Executor,
                        LiveExecutor, SimExecutor, executor_names,
                        get_executor, register_executor)
from .expander import DRAMExpander, ExpanderConfig, SingleFlight
from .policies import (make_expander, make_router, make_trigger,
                       policy_names, register_expander, register_router,
                       register_trigger)
from .router import AffinityRouter, ConsistentHashRing
from .topology import (ClusterTopology, Host, OwnerMap, make_prefill_hosts,
                       stripe_hosts)
from .runtime import (ClusterConfig, InstanceRuntime, PipelineConfig, Record,
                      RelayConfig, RelayRuntime, as_relay_config,
                      relay_config)
from .service import RelayGRService, ServiceConfig
from .trigger import SequenceAwareTrigger, TriggerConfig
from .types import (HASH_KEY, CacheState, HitKind, RankResult, Request,
                    Stage, UserMeta)
