"""RelayGR core: lifecycle caching under late-binding placement.

The paper's contribution as a composable library: sequence-aware trigger
(admission, Eqs. 1-3), affinity-aware router (placement, invariant I1),
memory-aware expander (DRAM reuse tier), HBM sliding-window cache
(invariant I2), and the ranking-instance engine + service composition.
"""
from .cache import CacheEntry, HBMCacheStore
from .costmodel import GRCostModel, HardwareModel
from .engine import (InstanceConfig, LiveExecutor, RankingInstance,
                     SimExecutor)
from .expander import DRAMExpander, ExpanderConfig, SingleFlight
from .router import AffinityRouter, ConsistentHashRing
from .service import RelayGRService, ServiceConfig
from .trigger import SequenceAwareTrigger, TriggerConfig
from .types import (HASH_KEY, CacheState, HitKind, RankResult, Request,
                    Stage, UserMeta)
