"""Cold tier: host-local SSD / remote psi store under the DRAM expander.

MTServe-style hierarchical caching (PAPERS.md): at capacity-harness
population scale (millions of users, Zipf tail) the DRAM expander's
LRU horizon is a few hundred entries, so every tail user falls
straight back to full re-inference the first time their DRAM copy is
evicted.  The cold tier catches those evictions — a ``ColdStore`` per
rank host holds demoted psi under a (large) byte budget, and a later
trigger-admitted visit *promotes* the copy back up the hierarchy off
the critical path.

The store itself is deliberately dumb: an LRU dict of dense
``CacheEntry`` copies with the unified tier counter family.  All
*timing* lives in the runtime — demotions and promotions are priced
through ``GRCostModel.psi_transfer_ms(link="cold")`` and serialized on
a per-host cold link that contends exactly like the NIC
(``RelayRuntime._cold_transfer``).  All *policy* lives in the trigger
(cold-aware admission scoring) and the runtime (promotion on the pre
path, lazy cross-host handoff on next touch after churn).

Counter family (every tier reports the same core so ``stats()``
renders one coherent table):

    inserts == live + evictions + handoffs + promotions

``evictions``  — LRU / replacement drops (the copy is gone);
``handoffs``   — extracted for a lazy cross-host re-home (extract !=
                 evict, same turnstile discipline as the HBM window);
``promotions`` — moved UP the hierarchy (cold -> DRAM revival).
Extras: ``hits`` / ``misses`` (runtime probes that did / did not find
a resident copy) and ``rejected_inserts`` (could never fit).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional

from .cache import CacheEntry, tenant_ledger
from .types import CacheState


@dataclasses.dataclass
class ColdStoreConfig:
    #: byte budget of the host's SSD namespace / remote-store share;
    #: 0 disables the tier (``ClusterConfig.cold_budget_bytes``)
    budget_bytes: float = 0.0


class ColdStore:
    """LRU cold store for demoted psi (one per rank host)."""

    def __init__(self, cfg: ColdStoreConfig,
                 tenant_quota: Optional[Dict[int, int]] = None):
        self.cfg = cfg
        self.entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self.used_bytes = 0
        self.stats: Dict[str, int] = {
            "inserts": 0, "evictions": 0, "handoffs": 0, "promotions": 0,
            "hits": 0, "misses": 0, "rejected_inserts": 0,
            "cross_tenant_evictions": 0,
        }
        # multi-tenant partition (same discipline as the HBM window and
        # DRAM expander): tenant id -> byte share; a tenant's demotion
        # only LRU-evicts that tenant's own copies.  None = untenanted.
        self.tenant_quota = ({int(t): int(b)
                              for t, b in tenant_quota.items()}
                             if tenant_quota is not None else None)
        self.tenant_used: Optional[Dict[int, int]] = (
            {t: 0 for t in self.tenant_quota}
            if self.tenant_quota is not None else None)
        self.tenant_stats = tenant_ledger(
            self.tenant_quota, "inserts", "evictions", "handoffs",
            "promotions", "hits")

    # --- tenant partition helpers ------------------------------------------
    def _tenant_budget(self, tenant: int) -> float:
        if self.tenant_quota is None:
            return self.cfg.budget_bytes
        return self.tenant_quota.get(int(tenant), 0)

    def _taccount(self, tenant: int, delta: int):
        if self.tenant_used is not None:
            t = int(tenant)
            self.tenant_used[t] = self.tenant_used.get(t, 0) + delta

    def _tbump(self, tenant: int, key: str, n: int = 1):
        if self.tenant_stats is not None:
            s = self.tenant_stats.get(int(tenant))
            if s is not None:
                s[key] = s.get(key, 0) + n

    def _lru_victim(self, tenant: int) -> Optional[int]:
        for uid, e in self.entries.items():
            if self.tenant_quota is not None and e.tenant != int(tenant):
                continue
            return uid
        return None

    @property
    def live_count(self) -> int:
        return len(self.entries)

    # --- writes (demotion landings) -----------------------------------------

    def insert(self, entry: CacheEntry) -> bool:
        """Land a demoted copy.  Replaces any stale copy of the same
        user (counted as an eviction — the old bytes are gone), LRU-
        evicts until the budget fits, and rejects entries that could
        never fit.  The entry must carry a dense ``value`` (the DRAM
        tier materializes paged psi at spill time)."""
        if entry.nbytes > self._tenant_budget(entry.tenant) \
                or entry.value is None:
            self.stats["rejected_inserts"] += 1
            return False
        self.drop(entry.user_id)            # stale same-user copy
        used = (self.tenant_used.get(int(entry.tenant), 0)
                if self.tenant_used is not None else self.used_bytes)
        while (used + entry.nbytes > self._tenant_budget(entry.tenant)
               and self.entries):
            old_uid = self._lru_victim(entry.tenant)
            if old_uid is None:
                break
            old = self.entries.pop(old_uid)
            self.used_bytes -= old.nbytes
            self._taccount(old.tenant, -old.nbytes)
            if old.tenant != entry.tenant:
                self.stats["cross_tenant_evictions"] += 1
            self.stats["evictions"] += 1
            self._tbump(old.tenant, "evictions")
            used = (self.tenant_used.get(int(entry.tenant), 0)
                    if self.tenant_used is not None else self.used_bytes)
        entry.state = CacheState.COLD
        self.entries[entry.user_id] = entry
        self.used_bytes += entry.nbytes
        self._taccount(entry.tenant, entry.nbytes)
        self.stats["inserts"] += 1
        self._tbump(entry.tenant, "inserts")
        return True

    # --- reads ---------------------------------------------------------------

    def peek(self, user_id: int) -> Optional[CacheEntry]:
        """Residency probe with NO accounting and no LRU touch — for
        admission-time scoring (the trigger's cold estimator) and the
        runtime's owner-locality checks."""
        return self.entries.get(user_id)

    def lookup(self, user_id: int) -> Optional[CacheEntry]:
        """Accounted probe: counts hit/miss and renews LRU position."""
        e = self.entries.get(user_id)
        if e is None:
            self.stats["misses"] += 1
            return None
        self.entries.move_to_end(user_id)
        self.stats["hits"] += 1
        self._tbump(e.tenant, "hits")
        return e

    # --- removals (the three turnstiles) ------------------------------------

    def take(self, user_id: int) -> Optional[CacheEntry]:
        """Remove for promotion up the hierarchy (cold -> DRAM)."""
        return self._remove(user_id, "promotions")

    def extract(self, user_id: int) -> Optional[CacheEntry]:
        """Remove for a lazy cross-host re-home: the entry is leaving
        this store but NOT the hierarchy (extract != evict)."""
        return self._remove(user_id, "handoffs")

    def drop(self, user_id: int) -> bool:
        """Discard a (stale) copy; counted as an eviction."""
        return self._remove(user_id, "evictions") is not None

    def _remove(self, user_id: int, counter: str) -> Optional[CacheEntry]:
        e = self.entries.pop(user_id, None)
        if e is None:
            return None
        self.used_bytes -= e.nbytes
        self._taccount(e.tenant, -e.nbytes)
        self.stats[counter] += 1
        self._tbump(e.tenant, counter)
        return e
