"""Streaming serving metrics: P² quantile estimation + windowed counters.

Production SLO enforcement needs online tail estimates without storing
every sample.  The P² algorithm (Jain & Chlamtac, 1985) maintains a
target quantile with five markers in O(1) per observation; `SLOTracker`
wraps one estimator per latency component plus success/QPS counters and
exports the same summary dict shape as the simulator — so the live
engine, the simulator and the benchmarks share observability plumbing.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional


class P2Quantile:
    """Single-quantile P² estimator (five-marker)."""

    def __init__(self, q: float = 0.99):
        self.q = q
        self._init: List[float] = []
        self.n = [0, 1, 2, 3, 4]
        self.ns = [0.0, 2 * q, 4 * q, 2 + 2 * q, 4.0]
        self.dns = [0.0, q / 2, q, (1 + q) / 2, 1.0]
        self.heights: List[float] = []
        self.count = 0

    def add(self, x: float):
        self.count += 1
        if len(self._init) < 5:
            self._init.append(x)
            if len(self._init) == 5:
                self._init.sort()
                self.heights = list(self._init)
            return
        h = self.heights
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            self.n[i] += 1
        for i in range(5):
            self.ns[i] += self.dns[i]
        for i in (1, 2, 3):
            d = self.ns[i] - self.n[i]
            if ((d >= 1 and self.n[i + 1] - self.n[i] > 1)
                    or (d <= -1 and self.n[i - 1] - self.n[i] < -1)):
                s = 1 if d >= 0 else -1
                hp = self._parabolic(i, s)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:
                    h[i] = h[i] + s * (h[i + s] - h[i]) \
                        / (self.n[i + s] - self.n[i])
                self.n[i] += s

    def _parabolic(self, i: int, s: int) -> float:
        h, n = self.heights, self.n
        return h[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    @property
    def value(self) -> float:
        if not self.heights:
            srt = sorted(self._init)
            if not srt:
                return float("nan")
            idx = min(int(self.q * len(srt)), len(srt) - 1)
            return srt[idx]
        return self.heights[2]


class WindowRate:
    """Completed-requests-per-second over a sliding time window."""

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self._times: deque = deque()

    def mark(self, now: float):
        self._times.append(now)
        cut = now - self.window_s
        while self._times and self._times[0] < cut:
            self._times.popleft()

    def rate(self, now: float) -> float:
        cut = now - self.window_s
        while self._times and self._times[0] < cut:
            self._times.popleft()
        return len(self._times) / self.window_s


@dataclasses.dataclass
class SLOTracker:
    slo_ms: float = 135.0
    quantile: float = 0.99

    def __post_init__(self):
        self.e2e = P2Quantile(self.quantile)
        self.components = {k: P2Quantile(self.quantile)
                           for k in ("pre", "load", "rank", "queue")}
        self.rate = WindowRate()
        self.total = 0
        self.ok = 0
        self.hits: Dict[str, int] = {}

    def observe(self, *, now: float, e2e_ms: float, hit: str,
                components: Optional[Dict[str, float]] = None):
        self.total += 1
        self.ok += e2e_ms <= self.slo_ms
        self.e2e.add(e2e_ms)
        self.rate.mark(now)
        self.hits[hit] = self.hits.get(hit, 0) + 1
        for k, v in (components or {}).items():
            if k in self.components:
                self.components[k].add(v)

    def summary(self, now: float) -> Dict[str, float]:
        n = max(self.total, 1)
        out = {
            "n": self.total,
            "p99_ms": self.e2e.value,
            "success_rate": self.ok / n,
            "throughput_qps": self.rate.rate(now),
        }
        for k, est in self.components.items():
            out[f"{k}_p99_ms"] = est.value
        for k, v in self.hits.items():
            out[f"hit_{k}"] = v / n
        return out
