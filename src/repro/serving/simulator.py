"""Discrete-event cluster simulator for RelayGR — runtime adapter.

The simulator is now a thin virtual-clock adapter over the canonical
event-driven state machine in ``repro.core.runtime`` (``RelayRuntime``):
trigger admission, affinity routing, HBM window, expander single-flight,
M model slots and the bounded-concurrency PCIe channel all execute in
the runtime, identically to live mode — the simulator merely feeds it a
timed arrival stream under a ``VirtualClock`` so cluster-scale P99 /
throughput traces replay in milliseconds without real NPUs.  Per-
operation latencies come from ``repro.core.costmodel`` via the ``sim``
executor, calibrated against the paper's reported component numbers.

``SimConfig`` and ``PipelineConfig`` remain importable here as
deprecation shims; new code should build a ``RelayConfig`` via
``repro.core.runtime.relay_config``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Iterable, List, Tuple

from repro.core.clock import VirtualClock
from repro.core.costmodel import GRCostModel
from repro.core.runtime import (ClusterConfig, PipelineConfig, Record,
                                RelayConfig, RelayRuntime, as_relay_config,
                                relay_config)
from repro.core.trigger import TriggerConfig
from repro.core.types import UserMeta

__all__ = ["ClusterSim", "PipelineConfig", "Record", "SimConfig", "run_sim"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """DEPRECATED: use ``relay_config(trigger=..., cluster=...)``."""
    pipeline: PipelineConfig = PipelineConfig()
    trigger: TriggerConfig = TriggerConfig(n_instances=10)
    relay_enabled: bool = True           # False -> baseline
    dram_budget_bytes: float = 500e9
    hbm_cache_bytes: float = 16e9
    m_slots: int = 5
    pcie_concurrency: int = 4
    seed: int = 0

    def __post_init__(self):
        warnings.warn(
            "SimConfig is deprecated; build a RelayConfig with "
            "repro.core.runtime.relay_config(trigger=..., cluster=...)",
            DeprecationWarning, stacklevel=3)

    def to_relay(self) -> RelayConfig:
        return relay_config(
            trigger=self.trigger, pipeline=self.pipeline,
            cluster=ClusterConfig(
                relay_enabled=self.relay_enabled,
                dram_budget_bytes=self.dram_budget_bytes,
                hbm_cache_bytes=self.hbm_cache_bytes,
                m_slots=self.m_slots,
                pcie_concurrency=self.pcie_concurrency,
                seed=self.seed))


class ClusterSim:
    """Virtual-clock adapter: replay a timed arrival stream through the
    shared relay-race runtime and report cluster-scale metrics."""

    def __init__(self, cfg, cost: GRCostModel, executor_factory=None):
        self.cfg = as_relay_config(cfg)
        self.runtime = RelayRuntime(self.cfg, cost, executor_factory,
                                    clock=VirtualClock())

    # --- adapter surface ----------------------------------------------------

    @property
    def instances(self) -> Dict:
        return self.runtime.instances

    @property
    def router(self):
        return self.runtime.router

    @property
    def topology(self):
        return self.runtime.topology

    @property
    def trigger(self):
        return self.runtime.trigger

    @property
    def special(self) -> List[str]:
        return self.runtime.special

    @property
    def normal(self) -> List[str]:
        return self.runtime.normal

    @property
    def records(self) -> List[Record]:
        return self.runtime.records

    @property
    def now(self) -> float:
        return self.runtime.now

    def run(self, arrivals: Iterable[Tuple[float, UserMeta]]
            ) -> Dict[str, float]:
        return self.runtime.run(arrivals)

    def summary(self) -> Dict[str, float]:
        return self.runtime.summary()


def run_sim(cfg, cost: GRCostModel,
            arrivals: Iterable[Tuple[float, UserMeta]]) -> Dict[str, float]:
    return ClusterSim(cfg, cost).run(arrivals)
