"""Discrete-event cluster simulator for RelayGR.

Replays the relay-race state machines (trigger admission, affinity
routing, HBM window, expander single-flight) under a virtual clock with
explicit resource contention:

  * each instance has M model slots (NPU concurrency) — pre-infer and
    ranking jobs queue for slots FIFO;
  * each instance has a bounded-concurrency H2D channel (PCIe) shared by
    embedding uploads and DRAM->HBM cache reloads;
  * out-of-order arrivals are exercised naturally: if ranking wins the
    race against its own pre-infer signal, the pseudo-pre-infer step
    parks the ranking job on the user's single-flight queue until psi
    lands in HBM (at-most-one reload / compute per user per burst).

This is how the paper-figure benchmarks measure P99 latency, SLO
-compliant throughput and maximum supported sequence length without real
NPUs; the per-operation latencies come from repro.core.costmodel, which
is calibrated against the paper's reported component numbers.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict, deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.cache import HBMCacheStore
from repro.core.costmodel import GRCostModel
from repro.core.expander import DRAMExpander, ExpanderConfig
from repro.core.router import AffinityRouter
from repro.core.trigger import SequenceAwareTrigger, TriggerConfig
from repro.core.types import HitKind, UserMeta


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    retrieval_ms: float = 40.0
    preprocess_ms: float = 25.0
    trigger_signal_ms: float = 3.0       # retrieval-side-path delay
    pipeline_slo_ms: float = 135.0       # end-to-end P99 SLO
    rank_budget_ms: float = 50.0         # ranking-stage budget


@dataclasses.dataclass(frozen=True)
class SimConfig:
    pipeline: PipelineConfig = PipelineConfig()
    trigger: TriggerConfig = TriggerConfig(n_instances=10)
    relay_enabled: bool = True           # False -> baseline
    dram_budget_bytes: float = 500e9
    hbm_cache_bytes: float = 16e9
    m_slots: int = 5
    pcie_concurrency: int = 4
    seed: int = 0


@dataclasses.dataclass
class Record:
    user_id: int
    t_arrival: float
    prefix_len: int = 0
    t_done: float = 0.0
    rank_stage_ms: float = 0.0
    pre_ms: float = 0.0
    load_ms: float = 0.0
    rank_ms: float = 0.0
    queue_ms: float = 0.0
    hit: str = "miss"

    @property
    def e2e_ms(self) -> float:
        return (self.t_done - self.t_arrival) * 1e3


class _Instance:
    """Simulated ranking instance: slot queue + PCIe channel + caches."""

    def __init__(self, name: str, sim: "ClusterSim", special: bool):
        self.name = name
        self.sim = sim
        self.special = special
        self.free_slots = sim.cfg.m_slots
        self.queue: deque = deque()
        self.pcie_free = sim.cfg.pcie_concurrency
        self.pcie_queue: deque = deque()
        self.hbm = HBMCacheStore(int(sim.cfg.hbm_cache_bytes))
        self.expander = DRAMExpander(ExpanderConfig(
            dram_budget_bytes=sim.cfg.dram_budget_bytes,
            max_reload_concurrency=sim.cfg.pcie_concurrency))
        self.inflight_pre: set = set()
        self.user_waiters: Dict[int, List] = defaultdict(list)
        self.busy_ms = 0.0

    # --- slot scheduling ---------------------------------------------------
    def enqueue(self, job: dict, now: float):
        job.setdefault("t_enqueue", now)
        self.queue.append(job)
        self._maybe_start(now)

    def _maybe_start(self, now: float):
        while self.free_slots > 0 and self.queue:
            job = self.queue.popleft()
            self.free_slots -= 1
            self.sim.schedule(now, "job_start", inst=self, job=job)

    def release_slot(self, now: float):
        self.free_slots += 1
        self._maybe_start(now)

    # --- pcie channel --------------------------------------------------------
    def pcie_acquire(self, now: float, cb: Callable):
        if self.pcie_free > 0:
            self.pcie_free -= 1
            cb(now)
        else:
            self.pcie_queue.append(cb)

    def pcie_release(self, now: float):
        if self.pcie_queue:
            cb = self.pcie_queue.popleft()
            cb(now)
        else:
            self.pcie_free += 1


class ClusterSim:
    def __init__(self, cfg: SimConfig, cost: GRCostModel):
        self.cfg = cfg
        self.cost = cost
        self.trigger = SequenceAwareTrigger(cfg.trigger, cost)
        ns = cfg.trigger.n_special
        nn = max(cfg.trigger.n_instances - ns, 1)
        self.special = [f"special-{i}" for i in range(ns)]
        self.normal = [f"normal-{i}" for i in range(nn)]
        self.router = AffinityRouter(self.special, self.normal)
        self.instances = {n: _Instance(n, self, n.startswith("special"))
                          for n in self.special + self.normal}
        self.events: list = []
        self.records: List[Record] = []
        self._seq = itertools.count()
        self.now = 0.0

    # --- event machinery --------------------------------------------------
    def schedule(self, t: float, kind: str, **kw):
        heapq.heappush(self.events, (t, next(self._seq), kind, kw))

    def run(self, arrivals: Iterable[Tuple[float, UserMeta]]):
        for t, meta in arrivals:
            self.schedule(t, "arrival", meta=meta)
        while self.events:
            t, _, kind, kw = heapq.heappop(self.events)
            self.now = t
            getattr(self, f"_on_{kind}")(t, **kw)
        return self.summary()

    # --- pipeline stages -----------------------------------------------------
    def _on_arrival(self, t: float, meta: UserMeta):
        rec = Record(user_id=meta.user_id, t_arrival=t,
                     prefix_len=meta.prefix_len)
        pp = self.cfg.pipeline
        if self.cfg.relay_enabled:
            key_target = self.router.ring.route(meta.user_id)
            d = self.trigger.admit(meta, key_target, t)
            if d.admitted:
                self.schedule(t + pp.trigger_signal_ms / 1e3, "pre_signal",
                              meta=meta, target=key_target)
        t_rank = t + (pp.retrieval_ms + pp.preprocess_ms) / 1e3
        self.schedule(t_rank, "rank_arrival", meta=meta, rec=rec)

    def _on_pre_signal(self, t: float, meta: UserMeta, target: str):
        inst = self.instances[target]
        inst.inflight_pre.add(meta.user_id)
        inst.enqueue({"kind": "pre", "meta": meta}, t)

    def _on_rank_arrival(self, t: float, meta: UserMeta, rec: Record):
        if self.cfg.relay_enabled and self.trigger.assess(meta).at_risk:
            target = self.router.ring.route(meta.user_id)
        else:
            target = self.normal[meta.user_id % len(self.normal)]
        rec.t_rank_arrival = t
        self.instances[target].enqueue(
            {"kind": "rank", "meta": meta, "rec": rec}, t)

    # --- job execution ----------------------------------------------------------
    def _on_job_start(self, t: float, inst: _Instance, job: dict):
        meta = job["meta"]
        if job["kind"] == "pre":
            # dedup: psi already local (HBM or DRAM) -> pseudo step only.
            # Higher DRAM hit rates therefore reduce pre-inference work
            # and NPU utilization (paper Fig. 14b).
            if meta.user_id in inst.hbm:
                self.schedule(t, "pre_done", inst=inst, meta=meta, ms=0.0)
                return
            if inst.expander.entries.get(meta.user_id) is not None:
                ms = self.cost.dram_load_ms(meta.prefix_len)

                def start(t2, inst=inst, meta=meta, ms=ms):
                    self.schedule(t2 + ms / 1e3, "pre_reload_done",
                                  inst=inst, meta=meta, ms=ms)

                inst.pcie_acquire(t, start)
                return
            ms = self.cost.pre_infer_ms(meta.prefix_len)
            inst.busy_ms += ms
            self.schedule(t + ms / 1e3, "pre_done", inst=inst, meta=meta,
                          ms=ms)
            return
        # ranking job
        rec: Record = job["rec"]
        rec.queue_ms += (t - job["t_enqueue"]) * 1e3
        uid = meta.user_id
        if not self.cfg.relay_enabled:
            self._full_rank(t, inst, meta, rec)
            return
        action, entry = inst.expander.pseudo_pre_infer(uid, inst.hbm, t)
        if action == "hbm":
            self._rank_cached(t, inst, meta, rec, dram=False)
        elif action == "wait":
            inst.expander.finish(uid)
            if uid in inst.inflight_pre or inst.expander.flight.waiters(uid):
                # park on the user's single-flight queue; slot goes back
                inst.user_waiters[uid].append((job, rec))
                inst.release_slot(t)
            else:
                e = inst.hbm.lookup(uid)
                if e is not None:
                    self._rank_cached(t, inst, meta, rec, dram=False)
                else:
                    self._full_rank(t, inst, meta, rec)
        elif action == "reload":
            ms = self.cost.dram_load_ms(meta.prefix_len)

            def start_reload(t2, inst=inst, meta=meta, rec=rec, ms=ms):
                self.schedule(t2 + ms / 1e3, "reload_done", inst=inst,
                              meta=meta, rec=rec, ms=ms)

            inst.pcie_acquire(t, start_reload)
        else:  # miss
            if uid in inst.inflight_pre:
                # out-of-order: rank arrived before its pre-infer finished
                inst.user_waiters[uid].append((job, rec))
                inst.expander.finish(uid)
                inst.release_slot(t)
            else:
                inst.expander.finish(uid)
                self._full_rank(t, inst, meta, rec)

    def _rank_cached(self, t: float, inst: _Instance, meta: UserMeta,
                     rec: Record, dram: bool):
        ms = self.cost.rank_on_cache_ms(meta.prefix_len, meta.incr_len,
                                        meta.n_items)
        rec.rank_ms = ms
        rec.hit = HitKind.DRAM_HIT.value if dram else HitKind.HBM_HIT.value
        inst.busy_ms += ms
        self.schedule(t + ms / 1e3, "rank_done", inst=inst, meta=meta,
                      rec=rec)

    def _full_rank(self, t: float, inst: _Instance, meta: UserMeta,
                   rec: Record):
        ms = self.cost.full_rank_ms(meta.prefix_len, meta.incr_len,
                                    meta.n_items)
        rec.rank_ms = ms
        rec.hit = HitKind.MISS_FALLBACK.value
        inst.busy_ms += ms
        self.schedule(t + ms / 1e3, "rank_done", inst=inst, meta=meta,
                      rec=rec)

    # --- completions -------------------------------------------------------------
    def _on_pre_done(self, t: float, inst: _Instance, meta: UserMeta,
                     ms: float):
        uid = meta.user_id
        inst.inflight_pre.discard(uid)
        nbytes = self.cost.kv_bytes(meta.prefix_len)
        evicted = inst.hbm.insert(uid, ("psi", uid), nbytes, t,
                                  prefix_len=meta.prefix_len)
        for e in evicted:
            if e.consumed:
                inst.expander.spill(e)
        inst.release_slot(t)
        self._wake_waiters(t, inst, uid, pre_ms=ms)

    def _on_pre_reload_done(self, t: float, inst: _Instance, meta: UserMeta,
                            ms: float):
        uid = meta.user_id
        inst.inflight_pre.discard(uid)
        inst.pcie_release(t)
        inst.expander.complete_reload(uid, inst.hbm, t)
        inst.release_slot(t)
        self._wake_waiters(t, inst, uid)

    def _on_reload_done(self, t: float, inst: _Instance, meta: UserMeta,
                        rec: Record, ms: float):
        uid = meta.user_id
        rec.load_ms = ms
        inst.pcie_release(t)
        inst.expander.complete_reload(uid, inst.hbm, t)
        inst.expander.finish(uid)
        self._rank_cached(t, inst, meta, rec, dram=True)
        self._wake_waiters(t, inst, uid)

    def _wake_waiters(self, t: float, inst: _Instance, uid: int,
                      pre_ms: float = 0.0):
        for job, rec in inst.user_waiters.pop(uid, []):
            rec.pre_ms = max(rec.pre_ms, pre_ms)
            inst.enqueue(job, t)

    def _on_rank_done(self, t: float, inst: _Instance, meta: UserMeta,
                      rec: Record):
        uid = meta.user_id
        e = inst.hbm.consume(uid)
        if e is not None and self.cfg.dram_budget_bytes > 0:
            # proactive spill copy for short-term cross-request reuse
            snap = dataclasses.replace(e)
            inst.expander.spill(snap)
        rec.t_done = t
        rec.rank_stage_ms = rec.queue_ms + rec.load_ms + rec.rank_ms
        self.records.append(rec)
        inst.release_slot(t)

    # --- metrics -------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        if not self.records:
            return {"n": 0}
        pp = self.cfg.pipeline
        e2e = np.array([r.e2e_ms for r in self.records])
        rank_stage = np.array([r.rank_stage_ms for r in self.records])
        ok = e2e <= pp.pipeline_slo_ms
        dur = (max(r.t_done for r in self.records)
               - min(r.t_arrival for r in self.records))
        hits = defaultdict(int)
        for r in self.records:
            hits[r.hit] += 1
        n = len(self.records)
        return {
            "n": n,
            "p50_ms": float(np.percentile(e2e, 50)),
            "p99_ms": float(np.percentile(e2e, 99)),
            "rank_p99_ms": float(np.percentile(rank_stage, 99)),
            "success_rate": float(ok.mean()),
            "throughput_qps": n / max(dur, 1e-9),
            "goodput_qps": int(ok.sum()) / max(dur, 1e-9),
            "hbm_hit": hits[HitKind.HBM_HIT.value] / n,
            "dram_hit": hits[HitKind.DRAM_HIT.value] / n,
            "miss": hits[HitKind.MISS_FALLBACK.value] / n,
            "pre_p99_ms": float(np.percentile(
                [r.pre_ms for r in self.records], 99)),
            "load_p99_ms": float(np.percentile(
                [r.load_ms for r in self.records], 99)),
            "rank_ms_p99": float(np.percentile(
                [r.rank_ms for r in self.records], 99)),
            "special_util": self._util(self.special, dur),
            "normal_util": self._util(self.normal, dur),
        }

    def _util(self, names, dur) -> float:
        if not names or dur <= 0:
            return 0.0
        busy = sum(self.instances[n].busy_ms for n in names) / 1e3
        return busy / (dur * self.cfg.m_slots * len(names))


def run_sim(cfg: SimConfig, cost: GRCostModel,
            arrivals: Iterable[Tuple[float, UserMeta]]) -> Dict[str, float]:
    return ClusterSim(cfg, cost).run(arrivals)
