"""Continuous micro-batching for ranking instances.

The paper's "M model slots" (§3.2, Fig. 7) abstracts NPU-side
concurrency.  On a real accelerator the equivalent mechanism is
*batched execution with bucketed shapes*: ranking requests that arrive
within a short window are grouped by (prefix-bucket, item-count) and
executed as one jitted call, amortizing dispatch and filling the MXU.

This module implements that layer for the live engine:

  * shape bucketing — prefix lengths round up to power-of-two-ish
    buckets so the jit cache stays small (a production system would
    pre-warm these);
  * a `BatchAggregator` that groups compatible requests up to
    ``max_batch`` or ``max_wait_ms``;
  * `BatchedRankExecutor` — drop-in for `LiveExecutor.rank_cached` that
    pads/stacks per-user psi caches and scores candidates for the whole
    group in one `rank_with_cache` call.

Correctness contract: batched scores equal per-request scores (same
mask semantics; padding keys are masked by zero-length contribution) —
asserted in tests/test_batching.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768)


def bucket_of(n: int) -> int:
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


@dataclasses.dataclass
class PendingRank:
    user_id: int
    psi: Any                      # per-layer (K, V), (L, 1, P, H, D)
    prefix_len: int
    incr: np.ndarray              # (n_incr,)
    items: np.ndarray             # (n_items,)
    enqueued_at: float = 0.0


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    max_batch: int = 8
    max_wait_ms: float = 2.0
    max_buckets_live: int = 4     # jit-cache pressure guard


class BatchAggregator:
    """Groups compatible pending requests into executable batches."""

    def __init__(self, cfg: BatchingConfig = BatchingConfig()):
        self.cfg = cfg
        self.queues: Dict[Tuple[int, int, int], List[PendingRank]] = \
            defaultdict(list)
        self.stats = {"batches": 0, "requests": 0, "max_seen_batch": 0}

    def _key(self, p: PendingRank) -> Tuple[int, int, int]:
        return (bucket_of(p.prefix_len), len(p.incr), len(p.items))

    def add(self, p: PendingRank, now: float) -> Optional[List[PendingRank]]:
        """Enqueue; returns a full batch if one is ready."""
        p.enqueued_at = now
        q = self.queues[self._key(p)]
        q.append(p)
        self.stats["requests"] += 1
        if len(q) >= self.cfg.max_batch:
            return self._take(self._key(p))
        return None

    def expired(self, now: float) -> List[List[PendingRank]]:
        """Batches whose oldest member exceeded max_wait_ms."""
        out = []
        for key in list(self.queues):
            q = self.queues[key]
            if q and (now - q[0].enqueued_at) * 1e3 >= self.cfg.max_wait_ms:
                out.append(self._take(key))
        return out

    def _take(self, key) -> List[PendingRank]:
        q = self.queues.pop(key, [])
        batch = q[: self.cfg.max_batch]
        rest = q[self.cfg.max_batch:]
        if rest:
            self.queues[key] = rest
        self.stats["batches"] += 1
        self.stats["max_seen_batch"] = max(self.stats["max_seen_batch"],
                                           len(batch))
        return batch


class BatchedRankExecutor:
    """Executes a batch of rank-with-cache requests in one jitted call.

    psi caches are padded to the shared prefix bucket: HSTU's pointwise
    attention with explicit 1/n normalization is *not* invariant to
    zero-padding keys (zero K rows still contribute silu(0)=0 — exactly
    nothing) so right-padding K/V with zeros is mask-free and exact;
    only the n_total normalizer must use the bucket length consistently
    for every request in the batch (same value the per-request call
    would use after bucketing).
    """

    def __init__(self, model, params):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))

    def _pad_psi(self, psi, target_len: int):
        jnp = self._jax.numpy
        k, v = psi
        pad = target_len - k.shape[2]
        if pad <= 0:
            return psi
        widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        return (jnp.pad(k, widths), jnp.pad(v, widths))

    def run(self, batch: Sequence[PendingRank]):
        jnp = self._jax.numpy
        bucket = bucket_of(max(p.prefix_len for p in batch))
        ks, vs = [], []
        for p in batch:
            k, v = self._pad_psi(p.psi, bucket)
            ks.append(k)
            vs.append(v)
        kv = (jnp.concatenate(ks, axis=1), jnp.concatenate(vs, axis=1))
        incr = jnp.asarray(np.stack([p.incr for p in batch]))
        items = jnp.asarray(np.stack([p.items for p in batch]))
        scores = self._rank(self.params, kv, incr, items)
        return [scores[i] for i in range(len(batch))]
