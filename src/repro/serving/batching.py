"""Continuous micro-batching for ranking instances.

The paper's "M model slots" (§3.2, Fig. 7) abstracts NPU-side
concurrency.  On a real accelerator the equivalent mechanism is
*batched execution with bucketed shapes*: ranking requests that arrive
within a short window are grouped by (kind, prefix-bucket, incr-len,
item-count) and executed as one jitted call, amortizing dispatch and
filling the MXU.

This module implements that layer for the live engine:

  * shape bucketing — prefix lengths round up to power-of-two-ish
    buckets so the jit cache stays small (``BatchedLiveExecutor.warmup``
    pre-compiles them at startup);
  * a `BatchAggregator` that groups compatible requests up to
    ``max_batch`` or ``max_wait_ms``;
  * `BatchedRankExecutor` — drop-in for `LiveExecutor.rank_cached` that
    pads/stacks per-user psi caches and scores candidates for the whole
    group in one `rank_with_cache` call.

The live relay path drives this layer through the registered ``batched``
executor (``repro.core.executors.BatchedLiveExecutor``): ``RelayRuntime``
enqueues ``PendingRank`` work into a per-instance ``BatchAggregator``
and flushes groups through one model slot each (see
``src/repro/core/README.md`` for the lifecycle).

Correctness contract: batched scores equal per-request scores (same
mask semantics; padding keys are masked by zero-length contribution) —
asserted in tests/test_batching.py.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768)


def bucket_of(n: int) -> int:
    for b in BUCKETS:
        if n <= b:
            return b
    return BUCKETS[-1]


def prefill_grid(n: int, grid: int = 64) -> int:
    """The prefill shape grid: prefix lengths round up to ``grid``
    tokens (the live executor's psi layout).  Batched pre-inference
    groups by THIS key — members of one group share the padded prefill
    length, so each member's psi slice is bit-identical to the psi its
    own per-request prefill would have produced."""
    return max(grid, (int(n) + grid - 1) // grid * grid)


def pad_psi(xp, psi, target_len: int):
    """Right-pad a per-layer (K, V) pytree — shapes (L, B, P, H, D) —
    with zero keys/values up to ``target_len`` along the P axis.

    Exact for HSTU's pointwise attention: zero K rows contribute
    silu(q . 0) = silu(0) = 0, so padded keys add literally nothing to
    the aggregation; only the 1/n_total normalizer must then use the
    padded length consistently, which every caller in a bucket does."""
    k, v = psi
    pad = target_len - k.shape[2]
    if pad <= 0:
        return psi
    widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
    return (xp.pad(k, widths), xp.pad(v, widths))


def stack_psi(xp, psis, bucket: int):
    """Pad each member's (K, V) to the shared prefix bucket and stack on
    the batch axis — THE group-launch cache layout, shared by the raw
    ``BatchedRankExecutor`` and ``BatchedLiveExecutor.rank_group``."""
    ks, vs = zip(*(pad_psi(xp, psi, bucket) for psi in psis))
    return (xp.concatenate(ks, axis=1), xp.concatenate(vs, axis=1))


@dataclasses.dataclass
class PendingRank:
    """One ranking request parked in the aggregator.

    ``psi`` is the cached per-layer (K, V) pytree for the rank-on-cache
    path, or ``None`` for a miss-fallback (full inference) member —
    the two kinds never share a batch.  ``incr``/``items`` carry the
    token arrays when the caller has them (raw ``BatchedRankExecutor``
    use); the runtime instead fills ``meta`` and the executor fetches
    tokens from its behaviour store."""
    user_id: int
    psi: Any                      # per-layer (K, V), (L, 1, P, H, D) | None
    prefix_len: int
    incr: Optional[np.ndarray] = None     # (n_incr,)
    items: Optional[np.ndarray] = None    # (n_items,)
    incr_len: int = 0
    n_items: int = 0
    meta: Any = None              # UserMeta (runtime-driven path)
    payload: Any = None           # opaque runtime job state rides along
    enqueued_at: float = 0.0

    def __post_init__(self):
        if self.incr is not None:
            self.incr_len = len(self.incr)
        elif self.meta is not None and not self.incr_len:
            self.incr_len = self.meta.incr_len
        if self.items is not None:
            self.n_items = len(self.items)
        elif self.meta is not None and not self.n_items:
            self.n_items = self.meta.n_items

    @property
    def kind(self) -> str:
        return "cached" if self.psi is not None else "full"


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    max_batch: int = 8
    max_wait_ms: float = 2.0
    max_buckets_live: int = 4     # jit-cache pressure guard (warmup)


class BatchAggregator:
    """Groups compatible pending requests into executable batches.

    The default compatibility key is the rank-launch shape key
    (kind, prefix-bucket, incr-len, item-count); pass ``key`` to group
    by something else (the pre-inference aggregator keys by the
    prefill grid instead — one jitted prefill per group)."""

    def __init__(self, cfg: BatchingConfig = BatchingConfig(), key=None):
        self.cfg = cfg
        self.queues: Dict[Tuple, List[PendingRank]] = defaultdict(list)
        self.stats = {"batches": 0, "requests": 0, "max_seen_batch": 0}
        if key is not None:
            self._key = key

    def _key(self, p: PendingRank) -> Tuple:
        return (p.kind, bucket_of(p.prefix_len), p.incr_len, p.n_items)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def depth_for(self, p: PendingRank) -> int:
        """Current queue depth of the group compatible with ``p``."""
        return len(self.queues.get(self._key(p), ()))

    def add(self, p: PendingRank, now: float) -> Optional[List[PendingRank]]:
        """Enqueue; returns a full batch if one is ready."""
        p.enqueued_at = now
        q = self.queues[self._key(p)]
        q.append(p)
        self.stats["requests"] += 1
        if len(q) >= self.cfg.max_batch:
            return self._take(self._key(p))
        return None

    def take_for(self, p: PendingRank) -> Optional[List[PendingRank]]:
        """Flush the (possibly partial) batch compatible with ``p`` now —
        the continuous-batching fast path: when a model slot is idle
        there is nothing to gain by waiting for co-batchable arrivals."""
        key = self._key(p)
        if self.queues.get(key):
            return self._take(key)
        return None

    def take_oldest(self) -> Optional[List[PendingRank]]:
        """Flush the group whose head has waited longest (slot-idle
        drain), regardless of deadline."""
        if not self.queues:
            return None
        key = min(self.queues, key=lambda k: self.queues[k][0].enqueued_at)
        return self._take(key)

    def expired(self, now: float) -> List[List[PendingRank]]:
        """Batches whose oldest member exceeded max_wait_ms (with a tiny
        epsilon so a flush timer scheduled at exactly +max_wait fires)."""
        out = []
        for key in list(self.queues):
            q = self.queues[key]
            if q and (now - q[0].enqueued_at) * 1e3 \
                    >= self.cfg.max_wait_ms - 1e-6:
                out.append(self._take(key))
        return out

    def _take(self, key) -> List[PendingRank]:
        q = self.queues.pop(key, [])
        batch = q[: self.cfg.max_batch]
        rest = q[self.cfg.max_batch:]
        if rest:
            self.queues[key] = rest
        self.stats["batches"] += 1
        self.stats["max_seen_batch"] = max(self.stats["max_seen_batch"],
                                           len(batch))
        return batch


class BatchedRankExecutor:
    """Executes a batch of rank-with-cache requests in one jitted call.

    psi caches are padded to the shared prefix bucket: HSTU's pointwise
    attention with explicit 1/n normalization is *not* invariant to
    zero-padding keys (zero K rows still contribute silu(0)=0 — exactly
    nothing) so right-padding K/V with zeros is mask-free and exact;
    only the n_total normalizer must use the bucket length consistently
    for every request in the batch (same value the per-request call
    would use after bucketing).
    """

    def __init__(self, model, params):
        import jax
        self._jax = jax
        self.model = model
        self.params = params
        self._rank = jax.jit(
            lambda p, kv, incr, items: model.rank_with_cache(
                p, kv, incr, items))

    def _pad_psi(self, psi, target_len: int):
        return pad_psi(self._jax.numpy, psi, target_len)

    def run(self, batch: Sequence[PendingRank]):
        jnp = self._jax.numpy
        bucket = bucket_of(max(p.prefix_len for p in batch))
        kv = stack_psi(jnp, [p.psi for p in batch], bucket)
        incr = jnp.asarray(np.stack([p.incr for p in batch]))
        items = jnp.asarray(np.stack([p.items for p in batch]))
        scores = self._rank(self.params, kv, incr, items)
        return [scores[i] for i in range(len(batch))]
