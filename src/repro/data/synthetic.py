"""Synthetic user-behaviour data substrate.

Deterministic, hash-seeded per-user behaviour streams mirroring the
paper's workload description (§4.1): most users have short histories,
<6% exceed 2K tokens (long-sequence users); items follow a Zipf
popularity law.  Used by the serving engine (behaviour fetch for
pre-inference), the trainer (next-item prediction batches) and the
benchmarks (request generators).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.types import UserMeta


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_users: int = 1_000_000
    vocab: int = 100_000
    zipf_a: float = 1.2
    # behaviour-length distribution: log-normal, calibrated so ~6% of
    # users exceed 2K tokens (paper §4.1)
    len_mu: float = 6.2          # median ~ e^6.2 ~ 490 tokens
    len_sigma: float = 0.95
    max_len: int = 32_768
    incr_len: int = 64
    n_items: int = 512
    dim: int = 256


class UserBehaviorStore:
    def __init__(self, cfg: WorkloadConfig = WorkloadConfig()):
        self.cfg = cfg

    def _rng(self, user_id: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([user_id & 0x7FFFFFFF, salt]))

    def prefix_len(self, user_id: int) -> int:
        rng = self._rng(user_id, 1)
        ln = int(np.exp(rng.normal(self.cfg.len_mu, self.cfg.len_sigma)))
        return int(np.clip(ln, 8, self.cfg.max_len))

    def meta(self, user_id: int) -> UserMeta:
        return UserMeta(user_id=user_id,
                        prefix_len=self.prefix_len(user_id),
                        incr_len=self.cfg.incr_len,
                        n_items=self.cfg.n_items,
                        dim=self.cfg.dim)

    def _zipf_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        # inverse-CDF Zipf over [0, vocab)
        u = rng.random(n)
        ranks = np.floor(np.exp(u * np.log(self.cfg.vocab))).astype(np.int64)
        return np.clip(ranks - 1, 0, self.cfg.vocab - 1).astype(np.int32)

    def long_term(self, user_id: int, length: Optional[int] = None
                  ) -> np.ndarray:
        n = length or self.prefix_len(user_id)
        return self._zipf_tokens(self._rng(user_id, 2), n)

    def short_term(self, user_id: int, trial: int = 0) -> np.ndarray:
        return self._zipf_tokens(self._rng(user_id, 100 + trial),
                                 self.cfg.incr_len)

    def candidates(self, user_id: int, trial: int = 0,
                   n_items: Optional[int] = None) -> np.ndarray:
        return self._zipf_tokens(self._rng(user_id, 10_000 + trial),
                                 n_items or self.cfg.n_items)

    # --- training pipeline ----------------------------------------------------
    def train_batches(self, batch_size: int, seq_len: int, *,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Next-item-prediction batches over synthetic behaviour streams."""
        rng = np.random.default_rng(seed)
        while True:
            uids = rng.integers(0, self.cfg.n_users, size=batch_size)
            toks = np.stack([
                np.resize(self.long_term(int(u), max(seq_len + 1, 16)),
                          seq_len + 1)
                for u in uids])
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}


def request_stream(store: UserBehaviorStore, qps: float, duration_s: float,
                   *, seed: int = 0, refresh_prob: float = 0.0,
                   refresh_horizon: int = 256, long_only: bool = False,
                   min_len: int = 0
                   ) -> Iterator[Tuple[float, UserMeta]]:
    """Poisson arrivals; with probability ``refresh_prob`` a request is a
    rapid-refresh repeat of a recent user (drives DRAM-tier reuse)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    recent: list = []
    while t < duration_s:
        t += rng.exponential(1.0 / qps)
        if recent and rng.random() < refresh_prob:
            uid = int(rng.choice(recent[-refresh_horizon:]))
        else:
            uid = int(rng.integers(0, store.cfg.n_users))
            if min_len and store.prefix_len(uid) < min_len:
                continue
        recent.append(uid)
        yield t, store.meta(uid)
