"""Synthetic user-behaviour data substrate.

Deterministic, hash-seeded per-user behaviour streams mirroring the
paper's workload description (§4.1): most users have short histories,
<6% exceed 2K tokens (long-sequence users); items follow a Zipf
popularity law.  Used by the serving engine (behaviour fetch for
pre-inference), the trainer (next-item prediction batches) and the
benchmarks (request generators).

Request-level workload layer (the capacity harness substrate):

  * ``ZipfPopularity`` — WHO arrives: a multi-million-user *request
    popularity* sampler (skew ``s=0`` is uniform; ``s>0`` draws user
    ranks from a bounded Zipf(s) law, so a head of hot users recurs
    within cache lifetimes and hit rates finally depend on footprint
    pressure instead of pinning at 100%);
  * ``arrival_times`` — WHEN they arrive: pluggable arrival processes
    (homogeneous Poisson, diurnal sinusoid via Lewis–Shedler thinning,
    MMPP-style two-state bursty), all normalized to a mean offered QPS;
  * ``capacity_stream`` — the composition: a timed
    ``(t, UserMeta)`` stream that feeds ``ClusterSim.run`` unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.types import UserMeta


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_users: int = 1_000_000
    vocab: int = 100_000
    zipf_a: float = 1.2
    # behaviour-length distribution: log-normal, calibrated so ~6% of
    # users exceed 2K tokens (paper §4.1)
    len_mu: float = 6.2          # median ~ e^6.2 ~ 490 tokens
    len_sigma: float = 0.95
    max_len: int = 32_768
    incr_len: int = 64
    n_items: int = 512
    dim: int = 256


class UserBehaviorStore:
    def __init__(self, cfg: WorkloadConfig = WorkloadConfig()):
        self.cfg = cfg

    def _rng(self, user_id: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([user_id & 0x7FFFFFFF, salt]))

    def prefix_len(self, user_id: int) -> int:
        rng = self._rng(user_id, 1)
        ln = int(np.exp(rng.normal(self.cfg.len_mu, self.cfg.len_sigma)))
        return int(np.clip(ln, 8, self.cfg.max_len))

    def meta(self, user_id: int) -> UserMeta:
        return UserMeta(user_id=user_id,
                        prefix_len=self.prefix_len(user_id),
                        incr_len=self.cfg.incr_len,
                        n_items=self.cfg.n_items,
                        dim=self.cfg.dim)

    def _zipf_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        # inverse-CDF Zipf over [0, vocab)
        u = rng.random(n)
        ranks = np.floor(np.exp(u * np.log(self.cfg.vocab))).astype(np.int64)
        return np.clip(ranks - 1, 0, self.cfg.vocab - 1).astype(np.int32)

    def long_term(self, user_id: int, length: Optional[int] = None
                  ) -> np.ndarray:
        n = length or self.prefix_len(user_id)
        return self._zipf_tokens(self._rng(user_id, 2), n)

    def short_term(self, user_id: int, trial: int = 0) -> np.ndarray:
        return self._zipf_tokens(self._rng(user_id, 100 + trial),
                                 self.cfg.incr_len)

    def candidates(self, user_id: int, trial: int = 0,
                   n_items: Optional[int] = None) -> np.ndarray:
        return self._zipf_tokens(self._rng(user_id, 10_000 + trial),
                                 n_items or self.cfg.n_items)

    # --- training pipeline ----------------------------------------------------
    def train_batches(self, batch_size: int, seq_len: int, *,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Next-item-prediction batches over synthetic behaviour streams."""
        rng = np.random.default_rng(seed)
        while True:
            uids = rng.integers(0, self.cfg.n_users, size=batch_size)
            toks = np.stack([
                np.resize(self.long_term(int(u), max(seq_len + 1, 16)),
                          seq_len + 1)
                for u in uids])
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# beyond-prefix segments (candidate-independent incr spans)
# ---------------------------------------------------------------------------


def segment_lens(user_id: int, incr_len: int, *, salt: int = 7,
                 max_segments: int = 2) -> Tuple[int, ...]:
    """Deterministic per-user candidate-independent segment lengths
    inside the incr region (RcLLM beyond-prefix reuse).  Drawn from a
    dedicated hash-seeded RNG keyed on the user id — NEVER from a
    stream's arrival/popularity RNG — so enabling segments leaves every
    existing trace's draws untouched.  Total segment mass is 40–75% of
    ``incr_len`` split across 1..``max_segments`` runs; the remainder
    stays fresh critical-path tokens."""
    if incr_len < 8:
        return ()
    rng = np.random.default_rng(
        np.random.SeedSequence([user_id & 0x7FFFFFFF, 7000 + salt]))
    k = int(rng.integers(1, max_segments + 1))
    total = int(incr_len * rng.uniform(0.4, 0.75))
    if total < k:
        return ()
    if k > 1:
        cuts = np.sort(rng.integers(1, total, size=k - 1))
    else:
        cuts = np.array([], dtype=np.int64)
    parts = np.diff(np.concatenate([[0], cuts, [total]]))
    return tuple(int(p) for p in parts if p > 0)


# ---------------------------------------------------------------------------
# request popularity (WHO arrives)
# ---------------------------------------------------------------------------


class ZipfPopularity:
    """Request-level user-popularity sampler over a ``population`` of
    user ids: rank-``r`` user receives a share of traffic ``∝ r^-skew``
    (bounded continuous Zipf, inverse-CDF sampled — O(1) per draw even
    for multi-million populations).  ``skew=0`` degenerates to the
    uniform draw the legacy benchmark streams used, where a repeat user
    is a once-in-a-billion event and HBM hit rates pin at 100%; real
    recommendation traffic is heavily head-skewed, which is what makes
    hit rate / P99 curves move with footprint pressure.

    The rank -> user-id mapping is the identity (popular users are the
    low ids); every consumer of a user id hashes it (rendezvous owner
    map, per-host rings, behaviour-store seeds), so contiguity carries
    no placement bias.
    """

    def __init__(self, population: int, skew: float = 0.0):
        if population < 1:
            raise ValueError(f"population must be >= 1, got {population}")
        if skew < 0:
            raise ValueError(f"skew must be >= 0, got {skew}")
        self.population = int(population)
        self.skew = float(skew)

    def cdf(self, rank: float) -> float:
        """Analytic share of requests landing on the top-``rank`` users
        (continuous bounded-Zipf CDF) — used by the statistical skew
        tests and by capacity reports to label workload head-heaviness."""
        n, s = self.population, self.skew
        rank = min(max(float(rank), 1.0), float(n))
        if n == 1:
            return 1.0
        if abs(s - 1.0) < 1e-9:
            return np.log(rank) / np.log(n)
        return (rank ** (1.0 - s) - 1.0) / (n ** (1.0 - s) - 1.0)

    def tail_share(self, rank: float) -> float:
        """Analytic share of requests landing BEYOND the top-``rank``
        users (1 - cdf): the fraction of traffic from the long tail a
        head-sized cache cannot hold — capacity reports use this to
        label how much load the sub-DRAM tiers are responsible for."""
        return 1.0 - self.cdf(rank)

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        """Draw ``n`` user ids (int64 array)."""
        u = rng.random(n)
        pop, s = self.population, self.skew
        if pop == 1:
            ranks = np.ones(n)
        elif abs(s - 1.0) < 1e-9:
            ranks = np.exp(u * np.log(pop))
        else:
            ranks = (1.0 + u * (pop ** (1.0 - s) - 1.0)) ** (1.0 / (1.0 - s))
        ids = np.floor(ranks).astype(np.int64) - 1
        return np.clip(ids, 0, pop - 1)

    def sample_one(self, rng: np.random.Generator) -> int:
        return int(self.sample(rng, 1)[0])


# ---------------------------------------------------------------------------
# arrival processes (WHEN they arrive)
# ---------------------------------------------------------------------------


def _poisson_arrivals(qps: float, duration_s: float,
                      rng: np.random.Generator) -> Iterator[float]:
    t = 0.0
    while True:
        t += rng.exponential(1.0 / qps)
        if t >= duration_s:
            return
        yield t


def _diurnal_arrivals(qps: float, duration_s: float,
                      rng: np.random.Generator, *, amp: float = 0.6,
                      period_s: float = 10.0) -> Iterator[float]:
    """Sinusoidal rate modulation ``λ(t) = qps·(1 + amp·sin(2πt/T))``
    via Lewis–Shedler thinning (exact for any bounded λ).  The mean
    rate over whole periods is ``qps``; the peak is ``(1+amp)·qps`` —
    a compressed diurnal cycle so a 12 s sim sees both the trough and
    the crest of a day."""
    if not 0.0 <= amp < 1.0:
        raise ValueError(f"diurnal amp must be in [0, 1), got {amp}")
    lam_max = qps * (1.0 + amp)
    t = 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_s:
            return
        lam_t = qps * (1.0 + amp * np.sin(2.0 * np.pi * t / period_s))
        if rng.random() * lam_max <= lam_t:
            yield t


def _mmpp_arrivals(qps: float, duration_s: float,
                   rng: np.random.Generator, *, low: float = 0.3,
                   high: float = 1.7, dwell_s: float = 1.0
                   ) -> Iterator[float]:
    """Two-state Markov-modulated Poisson process: the rate alternates
    between ``low·qps`` and ``high·qps`` with exponential dwell times
    (equal mean dwell in each state, so the stationary mean rate is
    ``(low+high)/2 · qps`` — keep ``low+high == 2`` to offer ``qps`` on
    average).  This is the bursty workload: multi-second on/off surges
    that queue the rank pool far beyond what Poisson at the same mean
    produces."""
    if low < 0 or high < low:
        raise ValueError(f"need 0 <= low <= high, got {low}, {high}")
    t, hot = 0.0, bool(rng.random() < 0.5)
    t_switch = rng.exponential(dwell_s)
    while True:
        rate = qps * (high if hot else low)
        # draw the next arrival in the current state; a state switch
        # before it invalidates the draw (memorylessness: redraw)
        gap = rng.exponential(1.0 / rate) if rate > 0 else float("inf")
        if t + gap >= t_switch:
            t = t_switch
            hot = not hot
            t_switch = t + rng.exponential(dwell_s)
            if t >= duration_s:
                return
            continue
        t += gap
        if t >= duration_s:
            return
        yield t


ARRIVAL_PROCESSES = {
    "poisson": _poisson_arrivals,
    "diurnal": _diurnal_arrivals,
    "mmpp": _mmpp_arrivals,
}


def arrival_times(process: str, qps: float, duration_s: float, *,
                  rng: np.random.Generator, **kw) -> Iterator[float]:
    """Arrival-time generator for one of ``ARRIVAL_PROCESSES`` (mean
    offered rate ``qps`` over ``duration_s`` seconds)."""
    try:
        fn = ARRIVAL_PROCESSES[process]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {process!r}; "
            f"known: {sorted(ARRIVAL_PROCESSES)}") from None
    if qps <= 0:
        return iter(())
    return fn(qps, duration_s, rng, **kw)


def capacity_stream(L: int, qps: float, duration_s: float, *,
                    skew: float = 0.0, population: int = 2_000_000,
                    arrival: str = "poisson", seed: int = 0,
                    dim: int = 256, n_items: int = 512,
                    incr_len: int = 64, arrival_kw: Optional[Dict] = None,
                    segments: bool = False, tenant: int = 0
                    ) -> Iterator[Tuple[float, UserMeta]]:
    """The capacity-harness request stream: WHO (Zipf(skew) popularity
    over ``population`` users) × WHEN (a named arrival process at mean
    ``qps``), at a fixed request profile (prefix ``L``, ``n_items``
    candidates).  Yields ``(t, UserMeta)`` and feeds ``ClusterSim.run``
    unchanged.  ``segments=True`` attaches per-user candidate-
    independent ``seg_lens`` from a separate hash RNG; ``tenant``
    stamps every request with a tenant id — neither consumes any
    stream RNG draw, so the arrival and popularity sequences are
    identical either way."""
    rng = np.random.default_rng(seed)
    pop = ZipfPopularity(population, skew)
    for t in arrival_times(arrival, qps, duration_s, rng=rng,
                           **(arrival_kw or {})):
        uid = pop.sample_one(rng)
        segs = segment_lens(uid, incr_len) if segments else ()
        yield t, UserMeta(user_id=uid, prefix_len=L, incr_len=incr_len,
                          dim=dim, n_items=n_items, seg_lens=segs,
                          tenant=int(tenant))


#: user-id stride between tenant workloads in ``multi_tenant_stream``:
#: far above any per-tenant ``population``, so tenants can never share
#: a cache key (the isolation guarantee starts at the workload layer)
TENANT_UID_STRIDE = 100_000_000


def multi_tenant_stream(mixes, duration_s: float, *, seed: int = 0
                        ) -> Iterator[Tuple[float, UserMeta]]:
    """Per-tenant traffic mixes merged into ONE timed request stream.

    ``mixes[i]`` is a dict of ``capacity_stream`` keyword args for
    tenant ``i`` — each tenant gets its own offered load, skew, prefix
    length and arrival process (e.g. tenant A steady Poisson, tenant B
    an MMPP burst for the isolation bench).  Isolation discipline:

      * every tenant draws from its OWN seeded RNG (``seed + 1000·i``
        unless the mix pins ``seed``), so one tenant's draw order can
        never perturb another's arrivals or popularity;
      * user ids live in DISJOINT per-tenant spaces (offset by
        ``i · TENANT_UID_STRIDE``) — tenants never share cache keys.

    Yields globally time-ordered ``(t, UserMeta)`` with
    ``UserMeta.tenant`` set, ready for ``RelayRuntime.run``."""
    import heapq

    def tagged(i: int, kw: Dict) -> Iterator[Tuple[float, UserMeta]]:
        kw = dict(kw)
        kw.setdefault("seed", seed + 1000 * i)
        kw["tenant"] = i
        for t, meta in capacity_stream(duration_s=duration_s, **kw):
            yield t, dataclasses.replace(
                meta, user_id=meta.user_id + i * TENANT_UID_STRIDE)

    return heapq.merge(*(tagged(i, kw) for i, kw in enumerate(mixes)),
                       key=lambda tm: tm[0])


def request_stream(store: UserBehaviorStore, qps: float, duration_s: float,
                   *, seed: int = 0, refresh_prob: float = 0.0,
                   refresh_horizon: int = 256, long_only: bool = False,
                   min_len: int = 0, segments: bool = False,
                   tenants: int = 1
                   ) -> Iterator[Tuple[float, UserMeta]]:
    """Poisson arrivals; with probability ``refresh_prob`` a request is a
    rapid-refresh repeat of a recent user (drives DRAM-tier reuse).
    ``segments=True`` attaches hash-derived per-user ``seg_lens``
    without consuming any stream RNG draws.  ``tenants > 1`` assigns
    each request a deterministic tenant (``user_id % tenants`` — a pure
    function of the id, no RNG draw), so the same trace replays
    identically with tenancy on or off."""
    rng = np.random.default_rng(seed)
    t = 0.0
    recent: list = []
    while t < duration_s:
        t += rng.exponential(1.0 / qps)
        if recent and rng.random() < refresh_prob:
            uid = int(rng.choice(recent[-refresh_horizon:]))
        else:
            uid = int(rng.integers(0, store.cfg.n_users))
            if min_len and store.prefix_len(uid) < min_len:
                continue
        recent.append(uid)
        m = store.meta(uid)
        if segments:
            m = dataclasses.replace(
                m, seg_lens=segment_lens(uid, m.incr_len))
        if tenants > 1:
            m = dataclasses.replace(m, tenant=uid % int(tenants))
        yield t, m
