"""Checkpointing: flat-key npz snapshots of (params, opt state, step).

Pure numpy container (no orbax dependency): pytree leaves are flattened
to ``path/to/leaf`` keys.  bfloat16 leaves are bit-cast to uint16 with a
dtype sidecar so ``np.savez`` round-trips them losslessly.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = leaf
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path, params, opt_state=None, step: int = 0):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(path.with_suffix(".npz"), **arrays)
    meta = {"step": int(step), "dtypes": dtypes}
    path.with_suffix(".json").write_text(json.dumps(meta))


def restore(path, template) -> Tuple[Any, Any, int]:
    """Restore into the structure of ``template`` ({'params':..,'opt':..})."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta = json.loads(path.with_suffix(".json").read_text())
    flat_t = _flatten(template)
    out = {}
    for k, tmpl in flat_t.items():
        a = data[k]
        want = meta["dtypes"][k]
        if want == "bfloat16":
            a = a.view(jnp.bfloat16)
        out[k] = jnp.asarray(a)
    leaves, treedef = jax.tree.flatten(template)
    keys = [ _SEP.join(_part(p) for p in path)
             for path, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    restored = jax.tree.unflatten(treedef, [out[k] for k in keys])
    return restored, meta["step"]
