"""AdamW + LR schedules in pure JAX (no optax dependency).

Optimizer state mirrors the param pytree (mu, nu) plus a scalar step;
shardings for mu/nu follow the param shardings (ZeRO-friendly: under
FSDP rules the state shards with the weights).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def init_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(abstract_params):
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return {"mu": z, "nu": z,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(param_axes, zero2: bool = False):
    """Optimizer-state logical axes.  ``zero2`` additionally shards the
    f32 mu/nu moments over the data axis on each weight's d_model dim
    (ZeRO-2: grads reduce-scatter into sharded state; weights stay
    replicated, so no sharding-propagation clash with remat residuals —
    see EXPERIMENTS.md §Perf zamba2-i3)."""
    axes = param_axes
    if zero2:
        axes = jax.tree.map(
            lambda t: tuple("opt_data" if a == "embed" else a for a in t),
            param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    return {"mu": axes, "nu": axes, "step": ()}


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), mu, nu

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(td, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(td, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(td, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
