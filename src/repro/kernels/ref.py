"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Shapes use the kernel-native layout (B, H, S, D); the ops.py wrappers
adapt from the model layout (B, S, H, D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hstu_attn_ref(q, k, v, *, n_total: float = None):
    """HSTU pointwise attention, causal.  q,k,v: (B, H, S, D)."""
    S = q.shape[2]
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    nt = n_total or S
    a = jax.nn.silu(logits) / nt
    mask = jnp.tril(jnp.ones((S, S), bool))
    a = jnp.where(mask[None, None], a, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", a.astype(v.dtype), v)


def rank_mask_ref(n_prefix: int, n_incr: int, n_items: int):
    """(Sq, Sk) ranking mask: incr causal; items see prefix+incr+self."""
    Sq = n_incr + n_items
    Sk = n_prefix + n_incr + n_items
    qi = np.arange(Sq)[:, None]
    ki = np.arange(Sk)[None, :]
    causal = ki <= (qi + n_prefix)
    is_item_q = qi >= n_incr
    is_item_k = ki >= n_prefix + n_incr
    self_key = ki == (qi + n_prefix)
    items_ok = np.where(is_item_q, (~is_item_k) | self_key, True)
    return jnp.asarray(causal & items_ok)


def prefix_rank_attn_ref(q, k, v, *, n_prefix: int, n_incr: int,
                         n_total: float = None):
    """Ranking-with-cache HSTU attention.

    q: (B, H, Sq, D) new tokens (incr + items);
    k, v: (B, H, Sk, D) with Sk = n_prefix + Sq (cached prefix concat new).
    """
    B, H, Sq, D = q.shape
    n_items = Sq - n_incr
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    nt = n_total or k.shape[2]
    a = jax.nn.silu(logits) / nt
    mask = rank_mask_ref(n_prefix, n_incr, n_items)
    a = jnp.where(mask[None, None], a, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", a.astype(v.dtype), v)


def segment_rank_attn_ref(q, k, v, *, q_pos, k_pos, n_items: int,
                          n_total: float = None):
    """Beyond-prefix (segment-reuse) ranking oracle.

    The FULL interleaved sequence — cached spans and fresh tokens in
    global position order — is ``k``/``v``: (B, H, S, D) with global
    positions ``k_pos`` (B, S).  Queries are the fresh tokens only:
    ``q`` (B, H, Sq, D) at positions ``q_pos`` (B, Sq), the last
    ``n_items`` of which are candidate items.  Mask semantics:

      * global-position causality — a fresh token attends every token
        at or before its own position, so a fresh token between two
        cached segments never sees the later segment;
      * candidate items attend all non-item context + themselves ONLY
        (the ``prefix_rank_attn_ref`` items rule, position-generalized).

    With one cached span at positions [0, P) and fresh tokens at
    [P, P+Sq) this reduces exactly to ``prefix_rank_attn_ref``.
    """
    B, H, Sq, D = q.shape
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    nt = n_total or k.shape[2]
    a = jax.nn.silu(logits) / nt
    qp = jnp.asarray(q_pos, jnp.int32)[:, :, None]      # (B, Sq, 1)
    kp = jnp.asarray(k_pos, jnp.int32)[:, None, :]      # (B, 1, S)
    causal = kp <= qp
    if n_items:
        is_item_q = (np.arange(Sq) >= Sq - n_items)[None, :, None]
        first_item = jnp.asarray(q_pos, jnp.int32)[:, Sq - n_items]
        is_item_k = kp >= first_item[:, None, None]
        self_key = kp == qp
        items_ok = jnp.where(is_item_q, (~is_item_k) | self_key, True)
    else:
        items_ok = True
    mask = jnp.logical_and(causal, items_ok)
    a = jnp.where(mask[:, None], a, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", a.astype(v.dtype), v)


def decode_attn_ref(q, k, v):
    """Softmax flash-decode oracle (GQA).

    q: (B, H, D) one query per sequence; k,v: (B, KV, S, D)."""
    B, H, D = q.shape
    KV = k.shape[1]
    kmap = jnp.arange(H) * KV // H
    ke = jnp.take(k, kmap, axis=1)          # (B, H, S, D)
    ve = jnp.take(v, kmap, axis=1)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhd,bhsd->bhs", q, ke).astype(jnp.float32) * scale
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", w.astype(v.dtype), ve)
