"""Pallas TPU kernel: Mamba2 SSD intra-chunk contraction.

The chunked SSD formulation (models/ssm.py) spends most of its FLOPs on
the per-chunk masked contraction

    M[q, t] = exp(cum[q] - cum[t]) * (C[q] . B[t]) * dt[t],  t <= q
    y[q]    = sum_t M[q, t] * x[t]

with Q = 128 chunk length — exactly one MXU tile.  This kernel fuses the
decay/mask/score elementwise chain between the two matmuls so the (Q, Q)
score tile never leaves VMEM; grid = (B, n_chunks, H) with per-head
blocks, so VMEM holds only (Q,N)+(Q,N)+(Q,P)+(Q,Q) ~ 200 KB.

Beyond-paper addition: the CUDA `mamba_chunk_scan` has no TPU port; this
is the MXU-native equivalent of its intra-chunk stage (the inter-chunk
recurrence stays a lax.scan over chunk summaries — it is O(L/Q) and
bandwidth-trivial).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(c_ref, b_ref, x_ref, cum_ref, dt_ref, o_ref):
    Q = c_ref.shape[2]
    c = c_ref[0, 0].astype(jnp.float32)          # (Q, N)
    b = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)    # (Q, P)
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)   # (Q,)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dec = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    m = jnp.where(ki <= qi, jnp.exp(dec), 0.0)
    mx = m * scores * dt[None, :]
    y = jax.lax.dot_general(mx, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0, :, 0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_intra(Cc, Bc, xc, cum, dtc, *, interpret: bool = False):
    """Cc, Bc: (B, nc, Q, N); xc: (B, nc, Q, H, P);
    cum, dtc: (B, nc, Q, H).  Returns y_intra (B, nc, Q, H, P)."""
    B, nc, Q, N = Cc.shape
    H, P = xc.shape[3], xc.shape[4]
    return pl.pallas_call(
        _kernel,
        grid=(B, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, 1, P),
                               lambda b, c, h: (b, c, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, Q, H, P), xc.dtype),
        interpret=interpret,
    )(Cc, Bc, xc, cum, dtc)


def ssd_chunk_intra_ref(Cc, Bc, xc, cum, dtc):
    """Pure-jnp oracle (mirrors models/ssm.mamba2_forward intra-chunk)."""
    Q = Cc.shape[2]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(dec), 0.0)
    Mx = M * scores[..., None] * dtc[:, :, None, :, :]
    return jnp.einsum("bcqkh,bckhp->bcqhp", Mx,
                      xc.astype(jnp.float32)).astype(xc.dtype)


# ---------------------------------------------------------------------------
# Chunk-state summary kernel: S_c = sum_t exp(cum_last - cum_t) dt_t B_t (x) x_t
# (the other matmul-heavy stage of chunked SSD; the inter-chunk scan then
# runs over these (H, N, P) summaries)
# ---------------------------------------------------------------------------


def _state_kernel(b_ref, x_ref, cum_ref, dt_ref, o_ref):
    b = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)       # (Q, P)
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)   # (Q,)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    w = jnp.exp(cum[-1] - cum) * dt                 # decay to chunk end
    bw = b * w[:, None]                             # (Q, N)
    s = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (N, P)
    o_ref[0, 0, 0] = s.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_state(Bc, xc, cum, dtc, *, interpret: bool = False):
    """Bc: (B, nc, Q, N); xc: (B, nc, Q, H, P); cum/dtc: (B, nc, Q, H).
    Returns per-chunk states (B, nc, H, N, P)."""
    B, nc, Q, N = Bc.shape
    H, P = xc.shape[3], xc.shape[4]
    return pl.pallas_call(
        _state_kernel,
        grid=(B, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, N, P),
                               lambda b, c, h: (b, c, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, H, N, P), jnp.float32),
        interpret=interpret,
    )(Bc, xc, cum, dtc)


def ssd_chunk_state_ref(Bc, xc, cum, dtc):
    tail = cum[:, :, -1:, :] - cum
    return jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                      Bc.astype(jnp.float32),
                      jnp.exp(tail) * dtc, xc.astype(jnp.float32))
