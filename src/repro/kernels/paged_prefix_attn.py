"""Pallas TPU kernel: paged ranking-with-cache HSTU attention.

The paged consumption path of the RelayGR HBM window: the cached user
prefix psi lives in a fixed-size page pool (``repro.core.paging``) and
the kernel gathers K/V page-by-page through a *page-table BlockSpec
index map* (scalar-prefetch grid), so ranking reads psi straight from
pages — no dense re-materialization of the prefix ever exists in HBM.

Mask semantics are identical to ``prefix_rank_attn``:

  * incremental tokens attend causally over prefix + earlier incr;
  * candidate items attend to prefix + incr + themselves ONLY.

Because HSTU attention is pointwise (silu, fixed 1/n normalizer — no
softmax running max/denominator), the aggregation splits exactly into
a prefix part and a new-token part.  The kernel runs two phases that
share one f32 accumulation chain:

  phase 1  grid (B, H, nq, n_pages): K/V blocks fetched via
           ``table[b, ip]`` from the page pool; every query sees the
           whole prefix, so the only mask is per-row residency
           (``ip * page_tokens + j < prefix_len[b]``).  Emits the f32
           partial sums.
  phase 2  grid (B, H, nq, nk): the dense incr+item K/V with the
           n_prefix = 0 rank mask, accumulator INITIALIZED from the
           phase-1 partial — the accumulation order is therefore
           identical to the dense kernel's, so for page-aligned
           prefixes the scores match ``prefix_rank_attn`` (called with
           ``bk = page_tokens``) bit for bit.

Page tables are padded to the launch's page-count bucket with a *null
page* (an always-zero pool row): zero keys contribute silu(0) = 0 —
exactly nothing — so padding is mask-free, matching the dense bucketed
path's zero-padded psi.  Mixed prefix lengths ride in one launch via
the per-row ``prefix_lens`` scalars; the shared ``n_total`` normalizer
is the bucket's padded length, exactly what the dense bucketed caller
uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prefix_pages_kernel(table_ref, plen_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, *, scale, inv_n, page_tokens, n_pages):
    """Phase 1: accumulate the prefix contribution, one page per step."""
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)     # (page_tokens, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    a = jax.nn.silu(logits) * inv_n
    bq = q.shape[0]
    ki = ip * page_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (bq, page_tokens), 1)
    a = jnp.where(ki < plen_ref[b], a, 0.0)   # residency / padding mask
    acc_ref[...] += jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ip == n_pages - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...]


def _new_tokens_kernel(q_ref, k_ref, v_ref, part_ref, o_ref, acc_ref, *,
                       scale, inv_n, bq, bk, n_incr, n_kv_blocks):
    """Phase 2: the incr+item tokens with the n_prefix = 0 rank mask,
    chained onto the phase-1 partial sums."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = part_ref[0, 0]

    # prune: keys strictly after the latest query this block can see
    @pl.when(ik * bk <= iq * bq + (bq - 1))
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        a = jax.nn.silu(logits) * inv_n
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        causal = ki <= qi
        is_item_q = qi >= n_incr
        is_item_k = ki >= n_incr
        self_key = ki == qi
        items_ok = jnp.where(is_item_q,
                             jnp.logical_or(~is_item_k, self_key), True)
        a = jnp.where(jnp.logical_and(causal, items_ok), a, 0.0)
        acc_ref[...] += jax.lax.dot_general(
            a, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "n_incr", "bq", "bk", "n_total", "interpret"))
def paged_prefix_rank_attn(q, k_pages, v_pages, page_table, prefix_lens,
                           k_new, v_new, *, n_incr: int, bq: int = 128,
                           bk: int = 0, n_total: float = None,
                           interpret: bool = False):
    """Rank with psi gathered from the page pool.

    q:                (B, H, Sq, D)   incr + item queries
    k_pages, v_pages: (N + 1, page_tokens, H, D) pool buffers — row N is
                      the all-zero null page used to pad tables
    page_table:       (B, n_pages) int32 page ids for each row's prefix
                      (pad with the null page up to the bucket)
    prefix_lens:      (B,) int32 true prefix tokens per row
    k_new, v_new:     (B, H, Sq, D)   incr + item keys/values

    ``n_total`` defaults to the bucket's padded context,
    ``n_pages * page_tokens + Sq`` — the same normalizer the dense
    bucketed caller uses on zero-padded psi.  ``bk`` defaults to
    ``page_tokens`` so the phase-2 block decomposition continues the
    phase-1 page decomposition (bit-for-bit with the dense kernel).
    """
    B, H, Sq, D = q.shape
    page_tokens = k_pages.shape[1]
    n_pages = page_table.shape[1]
    bq = min(bq, Sq)
    bk = min(bk or page_tokens, Sq)
    assert Sq % bq == 0 and Sq % bk == 0, (Sq, bq, bk)
    nq, nk = Sq // bq, Sq // bk
    scale = 1.0 / np.sqrt(D)
    inv_n = 1.0 / (n_total or (n_pages * page_tokens + Sq))

    # --- phase 1: prefix pages via the page-table index map ---------------
    grid1 = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, prefix_lens
        grid=(B, H, nq, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda b, h, iq, ip, tr, lr: (b, h, iq, 0)),
            pl.BlockSpec((1, page_tokens, 1, D),
                         lambda b, h, iq, ip, tr, lr: (tr[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page_tokens, 1, D),
                         lambda b, h, iq, ip, tr, lr: (tr[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ip, tr, lr: (b, h, iq, 0)),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
    )
    kernel1 = functools.partial(
        _prefix_pages_kernel, scale=scale, inv_n=inv_n,
        page_tokens=page_tokens, n_pages=n_pages)
    partial = pl.pallas_call(
        kernel1, grid_spec=grid1,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), jnp.float32),
        interpret=interpret,
    )(page_table, prefix_lens, q, k_pages, v_pages)

    # --- phase 2: dense incr+items, accumulator chained from phase 1 ------
    kernel2 = functools.partial(
        _new_tokens_kernel, scale=scale, inv_n=inv_n, bq=bq, bk=bk,
        n_incr=n_incr, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel2,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k_new, v_new, partial)


def _segment_pages_kernel(table_ref, pos_ref, valid_ref, q_ref, qpos_ref,
                          k_ref, v_ref, o_ref, acc_ref, *, scale, inv_n,
                          page_tokens, n_pages):
    """Segment phase 1: accumulate the CACHED-SPAN contribution, one
    page per step.  Unlike the prefix kernel, pages carry arbitrary
    token spans: ``pos_ref[b, ip]`` is the page's global position base
    and ``valid_ref[b, ip]`` its resident token count, so the mask is
    per-(query, key) — residency AND global-position causality (a
    fresh token between two cached segments must not see the later
    segment; items' positions exceed every cached position, so the
    same causal test covers them)."""
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)     # (page_tokens, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    a = jax.nn.silu(logits) * inv_n
    bq = q.shape[0]
    j = jax.lax.broadcasted_iota(jnp.int32, (bq, page_tokens), 1)
    qp = qpos_ref[0].reshape(bq, 1)            # global query positions
    resident = j < valid_ref[b, ip]
    causal = pos_ref[b, ip] + j <= qp
    a = jnp.where(jnp.logical_and(resident, causal), a, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ip == n_pages - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "n_items", "bq", "bk", "n_total", "interpret"))
def segment_rank_attn(q, k_pages, v_pages, page_table, page_pos,
                      page_valid, q_pos, k_new, v_new, *, n_items: int,
                      bq: int = 128, bk: int = 0, n_total: float = None,
                      interpret: bool = False):
    """Rank with psi gathered from an ordered list of cached SPANS
    (beyond-prefix segment reuse, RcLLM-style): the prefix plus any
    candidate-independent interior segments live in the page pool; the
    fresh tokens interleave between them at their global positions.

    q:                (B, H, Sq, D) FRESH tokens (fresh incr + items)
    k_pages, v_pages: (N + 1, page_tokens, H, D) pool buffers — row N
                      is the all-zero null page used to pad tables
    page_table:       (B, n_pages) int32 page ids over the row's cached
                      spans, in span order (pad with the null page)
    page_pos:         (B, n_pages) int32 global position of each page's
                      first token (0 for null-padded slots)
    page_valid:       (B, n_pages) int32 resident tokens per page
                      (0 for null-padded slots)
    q_pos:            (B, Sq) int32 global positions of the fresh
                      tokens, strictly increasing per row; the last
                      ``n_items`` are the candidate items
    k_new, v_new:     (B, H, Sq, D) fresh keys/values (same positions)

    Phase 1 walks the span pages with the residency + global-position
    causal mask; phase 2 is the UNCHANGED dense new-token kernel (the
    fresh tokens share one position array, so local causality equals
    global causality), chained onto the phase-1 partials.  With a
    single span at positions [0, prefix_len) and
    ``q_pos = prefix_len + arange(Sq)`` every mask bit equals the
    prefix kernel's, so the degenerate call is bit-identical to
    ``paged_prefix_rank_attn`` (tests/test_kernels.py).
    """
    B, H, Sq, D = q.shape
    page_tokens = k_pages.shape[1]
    n_pages = page_table.shape[1]
    bq = min(bq, Sq)
    bk = min(bk or page_tokens, Sq)
    assert Sq % bq == 0 and Sq % bk == 0, (Sq, bq, bk)
    nq, nk = Sq // bq, Sq // bk
    scale = 1.0 / np.sqrt(D)
    inv_n = 1.0 / (n_total or (n_pages * page_tokens + Sq))

    # --- phase 1: cached spans via the segment-table index map ------------
    grid1 = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,           # page_table, page_pos, page_valid
        grid=(B, H, nq, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda b, h, iq, ip, tr, pr, vr: (b, h, iq, 0)),
            pl.BlockSpec((1, bq),
                         lambda b, h, iq, ip, tr, pr, vr: (b, iq)),
            pl.BlockSpec((1, page_tokens, 1, D),
                         lambda b, h, iq, ip, tr, pr, vr:
                         (tr[b, ip], 0, h, 0)),
            pl.BlockSpec((1, page_tokens, 1, D),
                         lambda b, h, iq, ip, tr, pr, vr:
                         (tr[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ip, tr, pr, vr:
                               (b, h, iq, 0)),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
    )
    kernel1 = functools.partial(
        _segment_pages_kernel, scale=scale, inv_n=inv_n,
        page_tokens=page_tokens, n_pages=n_pages)
    partial = pl.pallas_call(
        kernel1, grid_spec=grid1,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), jnp.float32),
        interpret=interpret,
    )(page_table, page_pos, page_valid, q, q_pos, k_pages, v_pages)

    # --- phase 2: dense fresh tokens, identical to the prefix path --------
    kernel2 = functools.partial(
        _new_tokens_kernel, scale=scale, inv_n=inv_n, bq=bq, bk=bk,
        n_incr=Sq - n_items, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel2,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k_new, v_new, partial)


def pack_segments(k_cached, v_cached, spans, page_tokens: int,
                  n_pages: int = None):
    """Test/reference helper: slice per-row cached tokens into span-
    aware pool buffers, mimicking what the span-aware paged store does
    at insert.  ``k_cached``/``v_cached`` are (B, H, C, D) with row
    ``b``'s cached tokens packed contiguously in span order;
    ``spans[b]`` is an ordered list of (global_start, length) pairs.
    Every span pads to whole pages (the store's residency unit).
    Returns (k_pages, v_pages, table, page_pos, page_valid) with the
    all-zero null page as the last pool row."""
    k_cached, v_cached = np.asarray(k_cached), np.asarray(v_cached)
    B, H, C, D = k_cached.shape
    per_row = [sum(-(-int(ln) // page_tokens) for _, ln in row)
               for row in spans]
    n_pages = n_pages or max(per_row)
    total = sum(per_row)
    kp = np.zeros((total + 1, page_tokens, H, D), k_cached.dtype)
    vp = np.zeros_like(kp)
    table = np.full((B, n_pages), total, np.int32)     # pad = null page
    page_pos = np.zeros((B, n_pages), np.int32)
    page_valid = np.zeros((B, n_pages), np.int32)
    pid = 0
    for b, row in enumerate(spans):
        off = 0           # consumed cached tokens within this row
        slot = 0
        for start, ln in row:
            for j in range(-(-int(ln) // page_tokens)):
                lo, hi = j * page_tokens, min((j + 1) * page_tokens,
                                              int(ln))
                kp[pid, :hi - lo] = np.moveaxis(
                    k_cached[b, :, off + lo:off + hi], 0, 1)
                vp[pid, :hi - lo] = np.moveaxis(
                    v_cached[b, :, off + lo:off + hi], 0, 1)
                table[b, slot] = pid
                page_pos[b, slot] = int(start) + lo
                page_valid[b, slot] = hi - lo
                pid += 1
                slot += 1
            off += int(ln)
    return kp, vp, table, page_pos, page_valid


def pack_pages(k_dense, v_dense, prefix_lens, page_tokens: int,
               n_pages: int = None):
    """Test/reference helper: slice dense per-row prefixes — (B, H, P,
    D) — into pool buffers + page tables, mimicking what the paged HBM
    store does at insert.  Returns (k_pages, v_pages, table (B, np),
    prefix_lens i32); the last pool row is the all-zero null page."""
    k_dense, v_dense = np.asarray(k_dense), np.asarray(v_dense)
    B, H, P, D = k_dense.shape
    plens = np.asarray(prefix_lens, np.int32)
    per_row = [-(-int(p) // page_tokens) for p in plens]
    n_pages = n_pages or max(per_row)
    total = sum(per_row)
    kp = np.zeros((total + 1, page_tokens, H, D), k_dense.dtype)
    vp = np.zeros_like(kp)
    table = np.full((B, n_pages), total, np.int32)     # pad = null page
    pid = 0
    for b in range(B):
        for j in range(per_row[b]):
            lo, hi = j * page_tokens, min((j + 1) * page_tokens, int(plens[b]))
            kp[pid, :hi - lo] = np.moveaxis(k_dense[b, :, lo:hi], 0, 1)
            vp[pid, :hi - lo] = np.moveaxis(v_dense[b, :, lo:hi], 0, 1)
            table[b, j] = pid
            pid += 1
    return kp, vp, table, plens
