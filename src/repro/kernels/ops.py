"""jit'd public wrappers around the Pallas kernels.

These adapt the model layout (B, S, H, D) to the kernel-native layout
(B, H, S, D), dispatch ``interpret=True`` automatically off-TPU (the
kernel body then runs as a Python/XLA interpretation on CPU — the
correctness path used by CI), and fall back to the pure-jnp oracle for
shapes the tiling cannot serve (e.g. sequences not divisible by the
block size during live serving with odd prefix lengths).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attn import decode_attn as _decode_attn
from .hstu_attn import hstu_attn as _hstu_attn
from .prefix_rank_attn import prefix_rank_attn as _prefix_rank_attn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _bsh_to_bhs(x):
    return jnp.swapaxes(x, 1, 2)


def hstu_attention(q, k, v, *, n_total=None, block_q=256, block_k=256):
    """q,k,v: (B, S, H, D) model layout. Causal HSTU attention."""
    S = q.shape[1]
    qt, kt, vt = map(_bsh_to_bhs, (q, k, v))
    if S % min(block_q, S) or S % min(block_k, S):
        return _bsh_to_bhs(ref.hstu_attn_ref(qt, kt, vt, n_total=n_total))
    out = _hstu_attn(qt, kt, vt, bq=block_q, bk=block_k, n_total=n_total,
                     interpret=not _on_tpu())
    return _bsh_to_bhs(out)


def rank_attention(q, k, v, *, n_prefix, n_incr, n_total=None,
                   block_q=128, block_k=256):
    """Ranking-with-cache attention, model layout (B, S, H, D)."""
    Sq, Sk = q.shape[1], k.shape[1]
    qt, kt, vt = map(_bsh_to_bhs, (q, k, v))
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return _bsh_to_bhs(ref.prefix_rank_attn_ref(
            qt, kt, vt, n_prefix=n_prefix, n_incr=n_incr, n_total=n_total))
    out = _prefix_rank_attn(qt, kt, vt, n_prefix=n_prefix, n_incr=n_incr,
                            bq=bq, bk=bk, n_total=n_total,
                            interpret=not _on_tpu())
    return _bsh_to_bhs(out)


def cache_decode_attention(q, k, v, *, block_k=512):
    """Flash-decode: q (B, 1, H, D); cache k, v (B, S, KV, D)."""
    B, _, H, D = q.shape
    S = k.shape[1]
    kt = jnp.swapaxes(k, 1, 2)  # (B, KV, S, D)
    vt = jnp.swapaxes(v, 1, 2)
    bk = min(block_k, S)
    if S % bk:
        return ref.decode_attn_ref(q[:, 0], kt, vt)[:, None]
    out = _decode_attn(q[:, 0], kt, vt, bk=bk, interpret=not _on_tpu())
    return out[:, None]  # (B, 1, H, D)
