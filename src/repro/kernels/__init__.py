"""Pallas TPU kernels for the GR serving hot spots.

hstu_attn        — HSTU pointwise (SiLU) causal attention (pre-inference)
prefix_rank_attn — ranking-with-cache attention (RelayGR consumption path)
decode_attn      — flash-decode softmax attention over a KV cache (LM serve)

Each kernel ships with a pure-jnp oracle in ref.py and a layout-adapting
jit wrapper in ops.py.  On CPU the kernels execute in interpret mode.
"""
from .ops import cache_decode_attention, hstu_attention, rank_attention
