"""Pallas TPU kernel: HSTU pointwise (SiLU) causal attention.

The GR ranking hot spot.  Unlike softmax attention there is no running
max/denominator — the accumulation is a plain masked sum — so the flash
pattern degenerates to a tiled matmul chain, which maps directly onto
the MXU:

  grid = (B, H, Sq/bq, Sk/bk); the kv-block axis is innermost, so the
  f32 accumulator scratch lives in VMEM across kv iterations and the
  output block is written once on the last kv step.

Block shapes are multiples of 128 on the lane dimension (MXU-aligned);
the causal test prunes fully-masked kv blocks via @pl.when.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, *, scale, inv_n, bq, bk,
            n_kv_blocks):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block pruning: a kv block strictly after the q block is dead
    @pl.when(ik * bk <= iq * bq + (bq - 1))
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        a = jax.nn.silu(logits) * inv_n
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        a = jnp.where(ki <= qi, a, 0.0)
        acc_ref[...] += jax.lax.dot_general(
            a, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "n_total", "interpret"))
def hstu_attn(q, k, v, *, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
              n_total: float = None, interpret: bool = False):
    """q, k, v: (B, H, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / np.sqrt(D)
    inv_n = 1.0 / (n_total or S)

    kernel = functools.partial(_kernel, scale=scale, inv_n=inv_n, bq=bq,
                               bk=bk, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
