"""Pallas TPU kernel: ranking-with-cache HSTU attention.

This is the RelayGR consumption path: queries are the incremental tokens
(short-term behaviours + cross features) followed by the candidate
items; keys/values are the cached user prefix psi concatenated with the
new tokens.  The mask encodes the ranking semantics:

  * incremental tokens attend causally over prefix + earlier incr;
  * candidate items attend to prefix + incr + themselves ONLY
    (candidate independence — items never see each other).

Grid/BlockSpec structure matches hstu_attn (kv axis innermost, f32 VMEM
accumulator, MXU-aligned tiles); the mask is computed from global
indices in-kernel, so no (Sq, Sk) mask tensor ever exists in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, *, scale, inv_n, bq, bk,
            n_prefix, n_incr, n_kv_blocks):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # prune: keys strictly after the latest query this block can see
    @pl.when(ik * bk <= iq * bq + (bq - 1) + n_prefix)
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        a = jax.nn.silu(logits) * inv_n
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        causal = ki <= qi + n_prefix
        is_item_q = qi >= n_incr
        is_item_k = ki >= n_prefix + n_incr
        self_key = ki == qi + n_prefix
        items_ok = jnp.where(is_item_q,
                             jnp.logical_or(~is_item_k, self_key), True)
        a = jnp.where(jnp.logical_and(causal, items_ok), a, 0.0)
        acc_ref[...] += jax.lax.dot_general(
            a, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _done():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "n_prefix", "n_incr", "bq", "bk", "n_total", "interpret"))
def prefix_rank_attn(q, k, v, *, n_prefix: int, n_incr: int,
                     bq: int = 128, bk: int = 256, n_total: float = None,
                     interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D), Sk = n_prefix + Sq."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    assert Sk == n_prefix + Sq, (Sk, n_prefix, Sq)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)
    inv_n = 1.0 / (n_total or Sk)

    kernel = functools.partial(
        _kernel, scale=scale, inv_n=inv_n, bq=bq, bk=bk,
        n_prefix=n_prefix, n_incr=n_incr, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
