"""Pallas TPU kernel: flash-decode softmax attention over a KV cache.

The generic-LM serve_step hot spot (decode_32k / long_500k shapes): one
query token per sequence attends over a seq_len-deep KV cache with GQA.
The kv-sequence axis is tiled over the innermost grid dimension with the
classic running-(max, denom, acc) online-softmax state held in VMEM
scratch; GQA is handled in the BlockSpec index map (q head h reads kv
head h*KV//H), so KV blocks are fetched once per q-head group.

m/l running scalars are stored as (1, 128) lanes (tile-aligned) rather
than true scalars.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, n_kv_blocks):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # s: (1, bk); online softmax update
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                            # (1, bk)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p)
    m_ref[...] = jnp.full_like(m_ref, m_new)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (1, D)

    @pl.when(ik == n_kv_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attn(q, k, v, *, bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (B, H, D); k, v: (B, KV, S, D) -> (B, H, D)."""
    B, H, D = q.shape
    _, KV, S, _ = k.shape
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / np.sqrt(D)
    group = H // KV

    kernel = functools.partial(_kernel, scale=scale, n_kv_blocks=nk)
    q4 = q[:, :, None, :]                             # (B, H, 1, D)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32),
                        pltpu.VMEM((1, 128), jnp.float32),
                        pltpu.VMEM((1, 128), jnp.float32)],
        interpret=interpret,
    )(q4, k, v)
    return out[:, :, 0, :]
