from .config import INPUT_SHAPES, InputShape, ModelConfig
from .registry import ALIASES, ARCH_IDS, build_model, get_config, get_model
