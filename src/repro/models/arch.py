"""Architecture assembly: dense / MoE / VLM / SSM / hybrid / enc-dec / HSTU.

All families implement the same protocol (duck-typed, see ``BaseModel``):

    param_specs() -> pytree[ParamSpec]
    init(rng) -> params
    loss(params, batch) -> (scalar, metrics)          # train_step target
    prefill(params, batch) -> (hidden/logits, cache)  # produce KV/state psi
    decode_step(params, cache, batch) -> (logits, cache)  # serve_step target
    cache_specs(batch, seq_len) -> (sds_tree, axes_tree)
    batch_specs(shape) -> dict[str, ShapeDtypeStruct]

Layers are stacked on a leading axis and driven by ``lax.scan`` so the
compiled HLO size is independent of depth (essential: the multi-pod
dry-run compiles 40-layer models on a single host CPU).  Training wraps
the scan body in ``jax.checkpoint`` (full remat between layers).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ssm as ssm_lib
from .config import InputShape, ModelConfig
from .layers import (ParamSpec, abstract_tree, attention, attention_specs,
                     axes_tree, cross_entropy, ffn, ffn_specs, init_tree,
                     rms_norm)
from .moe import moe_ffn, moe_specs, shared_expert_ffn
from .partitioning import constrain


def stack_specs(specs, n: int):
    """Add a leading stacked-layer dim to every ParamSpec in a tree."""
    def one(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, shape=(n,) + s.shape,
                                   axes=("layers",) + s.axes)
    return jax.tree.map(one, specs, is_leaf=lambda s: isinstance(s, ParamSpec))


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, vp, dt = cfg.d_model, cfg.vocab_padded, cfg.dtype
    return {
        "tok": ParamSpec((vp, d), ("vocab", "embed"), scale=1.0, dtype=dt),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "unembed": ParamSpec((d, vp), ("embed", "vocab"), dtype=dt),
    }


def _embed(params, tokens):
    e = jnp.take(params["tok"], tokens, axis=0)
    return constrain(e, ("batch", "seq", "embed"))


def _logits(params, x):
    x = rms_norm(x, params["final_norm"])
    lg = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return constrain(lg, ("batch", "seq", "vocab"))


CE_CHUNK = 512


def ce_loss(params, x, labels, cfg, chunk: int = CE_CHUNK):
    """Sequence-chunked cross-entropy: the (B, S, vocab_padded) logits
    tensor is the single largest training temp (e.g. 420 GB global f32
    for hstu-gr train_4k); computing the loss chunk-by-chunk with remat
    caps the live slice at (B, chunk, Vp) and lets XLA free each chunk.
    Identical value to the unchunked mean CE (sum/N)."""
    B, S, d = x.shape
    if S <= chunk or S % chunk:
        logits = _logits(params, x)
        return cross_entropy(logits, labels, cfg.vocab).mean()
    nc = S // chunk
    xc = jnp.swapaxes(x.reshape(B, nc, chunk, d), 0, 1)
    lc = jnp.swapaxes(labels.reshape(B, nc, chunk), 0, 1)

    def one(args):
        xx, ll = args
        logits = _logits(params, xx)
        return cross_entropy(logits, ll, cfg.vocab).sum()

    tot = jax.lax.map(jax.checkpoint(one), (xc, lc)).sum()
    return tot / (B * S)


class BaseModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # --- shared helpers -------------------------------------------------
    def init(self, rng):
        return init_tree(self.param_specs(), rng)

    def abstract_params(self):
        return abstract_tree(self.param_specs())

    def param_axes(self):
        return axes_tree(self.param_specs())

    def batch_specs(self, shape: InputShape) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.dtype("int32")
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"token": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32)}

    def batch_axes(self, shape: InputShape) -> Dict[str, Any]:
        if shape.kind == "train":
            return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        if shape.kind == "prefill":
            return {"tokens": ("batch", "seq")}
        return {"token": ("batch", None), "pos": ("batch",)}


# ===========================================================================
# Dense / MoE / VLM decoder-only transformer
# ===========================================================================


class TransformerModel(BaseModel):
    """Decoder-only transformer: dense, MoE and VLM (stub frontend)."""

    @property
    def is_moe(self):
        return self.cfg.family == "moe"

    def block_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        specs = {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            "attn": attention_specs(cfg),
        }
        if self.is_moe:
            specs["moe"] = moe_specs(cfg)
        else:
            specs["ffn"] = ffn_specs(cfg)
        return specs

    def param_specs(self):
        cfg = self.cfg
        specs = dict(embed_specs(cfg))
        specs["layers"] = stack_specs(self.block_specs(), cfg.n_layers)
        if cfg.family == "vlm":
            # projector from (stubbed) vision embeddings into d_model
            specs["projector"] = ParamSpec(
                (cfg.d_model, cfg.d_model), ("embed", None), dtype=cfg.dtype)
        return specs

    # --- block ----------------------------------------------------------
    def _block(self, p, x, positions, cache=None, cache_index=None,
               window=0, prefix_len=0, causal=True):
        cfg = self.cfg
        h, kvc = attention(p["attn"], rms_norm(x, p["ln1"]), cfg,
                           positions=positions, cache=cache,
                           cache_index=cache_index, window=window,
                           causal=causal, prefix_len=prefix_len)
        x = x + h
        aux = jnp.zeros((), jnp.float32)
        if self.is_moe:
            y, aux = moe_ffn(p["moe"], rms_norm(x, p["ln2"]), cfg)
            if cfg.n_shared_experts:
                y = y + shared_expert_ffn(p["moe"], rms_norm(x, p["ln2"]),
                                          cfg)
        else:
            y = ffn(p["ffn"], rms_norm(x, p["ln2"]), cfg)
        return x + y, kvc, aux

    def _run(self, params, x, positions, cache=None, cache_index=None,
             window=0, prefix_len=0, remat=False):
        def body(carry, per_layer):
            xc, aux = carry
            pl, cl = per_layer
            y, kvc, a = self._block(pl, xc, positions, cache=cl,
                                    cache_index=cache_index, window=window,
                                    prefix_len=prefix_len)
            return (y, aux + a), kvc

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    (params["layers"], cache))
        return x, aux, kv

    # --- public protocol --------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = _embed(params, tokens)
        if cfg.family == "vlm":
            fe = batch["frontend"]
            fe = jnp.einsum("bfd,de->bfe", fe, params["projector"])
            x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux, _ = self._run(params, x, positions,
                              window=cfg.sliding_window, remat=True)
        if cfg.family == "vlm":
            x = x[:, cfg.n_frontend_tokens:]
        ce = ce_loss(params, x, batch["labels"], cfg)
        return ce + aux, {"ce": ce, "aux": aux}

    def prefill(self, params, batch):
        cfg = self.cfg
        x = _embed(params, batch["tokens"])
        if cfg.family == "vlm" and "frontend" in batch:
            fe = jnp.einsum("bfd,de->bfe", batch["frontend"],
                            params["projector"])
            x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, kv = self._run(params, x, positions,
                             window=cfg.sliding_window)
        return _logits(params, x[:, -1:]), kv

    def decode_step(self, params, cache, batch):
        positions = batch["pos"][:, None]
        x = _embed(params, batch["token"])
        x, _, kv = self._run(params, x, positions, cache=cache,
                             cache_index=batch["pos"])
        return _logits(params, x), kv

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
        cache_dt = jnp.int8 if cfg.kv_quant else jnp.dtype(cfg.dtype)
        kv_sds = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim),
            cache_dt)
        # long-context dense decode: shard the cache sequence over "data"
        seq_ax = "kv_seq" if (batch == 1 and seq_len >= 65536) else None
        axes = ("layers", "batch", seq_ax, "kv_heads", None)
        if cfg.kv_quant:
            sc_sds = jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, S, cfg.n_kv_heads, 1), jnp.float32)
            return ((kv_sds, kv_sds, sc_sds, sc_sds),
                    (axes, axes, axes, axes))
        return (kv_sds, kv_sds), (axes, axes)

    def init_cache(self, batch: int, seq_len: int):
        (ks, vs), _ = self.cache_specs(batch, seq_len)
        return (jnp.zeros(ks.shape, ks.dtype), jnp.zeros(vs.shape, vs.dtype))

    def batch_specs(self, shape: InputShape):
        specs = super().batch_specs(shape)
        cfg = self.cfg
        if cfg.family == "vlm" and shape.kind != "decode":
            specs["frontend"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return specs

    def batch_axes(self, shape: InputShape):
        axes = super().batch_axes(shape)
        if self.cfg.family == "vlm" and shape.kind != "decode":
            axes["frontend"] = ("batch", "frames", "embed")
        return axes


# ===========================================================================
# SSM stacks (Mamba2 / RWKV6)
# ===========================================================================


class SSMModel(BaseModel):
    """Attention-free stack; decode state is O(1) in sequence length."""

    @property
    def is_mamba(self):
        return self.cfg.family == "ssm_mamba2"

    def block_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        mixer = (ssm_lib.mamba2_specs(cfg) if self.is_mamba
                 else ssm_lib.rwkv6_specs(cfg))
        return {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            "mixer": mixer,
            "ffn": ffn_specs(cfg),
        }

    def param_specs(self):
        cfg = self.cfg
        specs = dict(embed_specs(cfg))
        specs["layers"] = stack_specs(self.block_specs(), cfg.n_layers)
        return specs

    def _mix(self, p, x, state, decode):
        cfg = self.cfg
        if self.is_mamba:
            if decode:
                return ssm_lib.mamba2_decode(p, x, cfg, state)
            return ssm_lib.mamba2_forward(p, x, cfg, state)
        return ssm_lib.rwkv6_forward(p, x, cfg, state)

    def _run(self, params, x, state=None, decode=False, remat=False):
        def body(xc, per_layer):
            pl, sl = per_layer
            h, s2 = self._mix(pl["mixer"], rms_norm(xc, pl["ln1"]), sl,
                              decode)
            xc = xc + h
            xc = xc + ffn(pl["ffn"], rms_norm(xc, pl["ln2"]), self.cfg)
            return xc, s2

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, states = jax.lax.scan(body, x, (params["layers"], state))
        return x, states

    def _zero_state(self, batch):
        sds, _ = self.cache_specs(batch, 0)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def loss(self, params, batch):
        x = _embed(params, batch["tokens"])
        x, _ = self._run(params, x, state=self._zero_state(x.shape[0]),
                         remat=True)
        ce = ce_loss(params, x, batch["labels"], self.cfg)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        x = _embed(params, batch["tokens"])
        x, states = self._run(params, x,
                              state=self._zero_state(x.shape[0]))
        return _logits(params, x[:, -1:]), states

    def decode_step(self, params, cache, batch):
        x = _embed(params, batch["token"])
        x, states = self._run(params, x, state=cache, decode=True)
        return _logits(params, x), states

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        per = (ssm_lib.mamba2_state_specs(cfg, batch) if self.is_mamba
               else ssm_lib.rwkv6_state_specs(cfg, batch))
        sds = tuple(jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype)
                    for s, _ in per)
        axes = tuple(("layers",) + a for _, a in per)
        return sds, axes

    def init_cache(self, batch: int, seq_len: int):
        sds, _ = self.cache_specs(batch, seq_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)


# ===========================================================================
# Hybrid (Zamba2): mamba2 backbone + shared attention blocks
# ===========================================================================


class HybridModel(BaseModel):
    """``n_layers`` mamba blocks; a *shared-weight* GQA block (with
    per-invocation LoRA on the query projection) is applied after every
    ``attn_every`` mamba layers — Zamba2's shared-attention pattern."""

    LORA_R = 32

    def __init__(self, cfg):
        super().__init__(cfg)
        self.n_sections = cfg.n_layers // cfg.attn_every
        self.n_tail = cfg.n_layers - self.n_sections * cfg.attn_every

    def param_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        mamba_block = {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            "mixer": ssm_lib.mamba2_specs(cfg),
            "ffn": ffn_specs(cfg),
        }
        specs = dict(embed_specs(cfg))
        specs["sections"] = stack_specs(
            stack_specs(mamba_block, cfg.attn_every), self.n_sections)
        if self.n_tail:
            specs["tail"] = stack_specs(mamba_block, self.n_tail)
        specs["shared_attn"] = {
            "ln": ParamSpec((d,), ("embed",), init="ones"),
            "attn": attention_specs(cfg),
            "lora_a": ParamSpec((self.n_sections, d, self.LORA_R),
                                (None, "embed", None), dtype=cfg.dtype),
            "lora_b": ParamSpec(
                (self.n_sections, self.LORA_R, cfg.n_heads, cfg.head_dim),
                (None, None, "heads", None), init="zeros", dtype=cfg.dtype),
        }
        return specs

    def _mamba_scan(self, stacked, x, states, decode, remat=False):
        def body(xc, per_layer):
            pl, sl = per_layer
            fwd = ssm_lib.mamba2_decode if decode else ssm_lib.mamba2_forward
            h, s2 = fwd(pl["mixer"], rms_norm(xc, pl["ln1"]), self.cfg, sl)
            xc = xc + h
            xc = xc + ffn(pl["ffn"], rms_norm(xc, pl["ln2"]), self.cfg)
            return xc, s2

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, (stacked, states))

    def _shared_attn(self, params, x, sec, positions, cache=None,
                     cache_index=None):
        p = params["shared_attn"]
        xn = rms_norm(x, p["ln"])
        lora = jnp.einsum("bsd,dr,rhk->bshk", xn, p["lora_a"][sec],
                          p["lora_b"][sec])
        h, kv = attention(p["attn"], xn, self.cfg, positions=positions,
                          cache=cache, cache_index=cache_index)
        return x + h + jnp.einsum("bshk,hkd->bsd", lora,
                                  p["attn"]["wo"]), kv

    def _run(self, params, x, mstates, astates, positions, decode,
             cache_index=None, remat=False):
        cfg = self.cfg
        new_m, new_a = [], []
        shared_fn = self._shared_attn
        if remat:
            # the 6 shared-attention invocations are python-unrolled (not
            # inside the mamba scan); without remat each keeps its (B, H,
            # S, S) f32 score tensor + qkv alive for the backward pass —
            # ~13 GB/chip at train_4k (see EXPERIMENTS.md §Perf zamba2-i2)
            shared_fn = jax.checkpoint(
                self._shared_attn, static_argnums=(2,),
                policy=jax.checkpoint_policies.nothing_saveable)
        for sec in range(self.n_sections):
            stacked = jax.tree.map(lambda t: t[sec], params["sections"])
            st = jax.tree.map(lambda t: t[sec], mstates["sections"])
            x, s2 = self._mamba_scan(stacked, x, st, decode, remat)
            new_m.append(s2)
            ac = (jax.tree.map(lambda t: t[sec], astates)
                  if astates is not None else None)
            x, kv = shared_fn(params, x, sec, positions,
                              cache=ac, cache_index=cache_index)
            new_a.append(kv)
        if self.n_tail:
            x, s_tail = self._mamba_scan(params["tail"], x,
                                         mstates["tail"], decode, remat)
        else:
            s_tail = mstates["tail"]
        mst = {"sections": jax.tree.map(lambda *t: jnp.stack(t), *new_m),
               "tail": s_tail}
        ast = jax.tree.map(lambda *t: jnp.stack(t), *new_a)
        return x, mst, ast

    def _zero_mstates(self, batch):
        sds, _ = self._mstate_specs(batch)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def _mstate_specs(self, batch):
        cfg = self.cfg
        per = ssm_lib.mamba2_state_specs(cfg, batch)
        def stk(n):
            sds = tuple(jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
                        for s, _ in per)
            axes = tuple(("layers",) + a for _, a in per)
            return sds, axes
        sec_sds, sec_axes = stk(cfg.attn_every)
        sds = {"sections": tuple(
            jax.ShapeDtypeStruct((self.n_sections,) + s.shape, s.dtype)
            for s in sec_sds)}
        axes = {"sections": tuple(("sections",) + a for a in sec_axes)}
        tail_sds, tail_axes = stk(max(self.n_tail, 1))
        sds["tail"] = tail_sds
        axes["tail"] = tail_axes
        return sds, axes

    def loss(self, params, batch):
        x = _embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, _ = self._run(params, x, self._zero_mstates(x.shape[0]), None,
                            positions, decode=False, remat=True)
        ce = ce_loss(params, x, batch["labels"], self.cfg)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        x = _embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        x, mst, ast = self._run(params, x, self._zero_mstates(x.shape[0]),
                                None, positions, decode=False)
        return _logits(params, x[:, -1:]), {"m": mst, "a": ast}

    def decode_step(self, params, cache, batch):
        x = _embed(params, batch["token"])
        positions = batch["pos"][:, None]
        x, mst, ast = self._run(params, x, cache["m"], cache["a"],
                                positions, decode=True,
                                cache_index=batch["pos"])
        return _logits(params, x), {"m": mst, "a": ast}

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        m_sds, m_axes = self._mstate_specs(batch)
        S = max(seq_len, 1)
        seq_ax = "kv_seq" if (batch == 1 and seq_len >= 65536) else None
        kv_sds = jax.ShapeDtypeStruct(
            (self.n_sections, batch, S, cfg.n_kv_heads, cfg.head_dim),
            jnp.dtype(cfg.dtype))
        kv_axes = ("sections", "batch", seq_ax, "kv_heads", None)
        return ({"m": m_sds, "a": (kv_sds, kv_sds)},
                {"m": m_axes, "a": (kv_axes, kv_axes)})

    def init_cache(self, batch: int, seq_len: int):
        sds, _ = self.cache_specs(batch, seq_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)
