"""Logical-axis partitioning rules.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", "vocab", "experts", ...).  A thread-local rule set maps logical axes
to mesh axes; outside of a mesh context every annotation is a no-op, so
the same model code runs on one CPU device and on a 512-chip mesh.

Weights additionally get a *param spec* derived from the same rules, used
for ``in_shardings`` when lowering.  FSDP-style weight sharding (ZeRO-3 on
the "data" axis) is switched per-mesh via ``fsdp=True``: the largest
non-model-sharded dimension of every weight is sharded over "data".
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_tls = threading.local()

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",      # dropped per-arch when not divisible
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "embed": None,            # becomes ("data",) under fsdp
    "opt_data": "data",       # ZeRO-2: optimizer-state-only sharding
    "kv_seq": None,           # long-context decode shards cache seq on data
    "seq": None,
    "ssm_heads": "model",
    "rwkv_heads": "model",
    "ssm_state": None,
    "frames": None,
}


class Rules:
    def __init__(self, mesh: Optional[Mesh], overrides=None, fsdp: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp
        self.table = dict(DEFAULT_RULES)
        if overrides:
            self.table.update(overrides)
        if fsdp:
            self.table["embed"] = "data"
        if mesh is not None:
            names = set(mesh.axis_names)
            resolved = {}
            for k, v in self.table.items():
                if v is None or v == "":
                    resolved[k] = None
                elif isinstance(v, tuple):
                    kept = tuple(a for a in v if a in names)
                    resolved[k] = kept if kept else None
                else:
                    resolved[k] = v if v in names else None
            self.table = resolved

    def axis_size(self, mesh_axis) -> int:
        if self.mesh is None or mesh_axis is None:
            return 1
        if isinstance(mesh_axis, tuple):
            n = 1
            for a in mesh_axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[mesh_axis]

    def spec(self, logical: Sequence[Optional[str]], shape=None) -> P:
        """Map logical axis names to a PartitionSpec.

        If ``shape`` is given, any axis whose size does not divide evenly
        by the mesh-axis size is dropped to None (replicated) — this is
        how e.g. 36 attention heads on a 16-way model axis degrade
        gracefully to replicated attention.
        """
        out = []
        used = set()
        for i, name in enumerate(logical):
            m = self.table.get(name) if name else None
            if m is not None and shape is not None:
                if shape[i] % self.axis_size(m) != 0:
                    m = None
            # a mesh axis may appear at most once in a spec
            key = m if not isinstance(m, tuple) else m
            if m is not None:
                flat = m if isinstance(m, tuple) else (m,)
                if any(a in used for a in flat):
                    m = None
                else:
                    used.update(flat)
            out.append(m)
        return P(*out)


@contextlib.contextmanager
def logical_rules(mesh: Optional[Mesh], overrides=None, fsdp: bool = False):
    prev = getattr(_tls, "rules", None)
    _tls.rules = Rules(mesh, overrides, fsdp)
    try:
        yield _tls.rules
    finally:
        _tls.rules = prev


def current_rules() -> Optional[Rules]:
    return getattr(_tls, "rules", None)


def constrain(x, logical: Sequence[Optional[str]]):
    """Apply a sharding constraint inside jit, or no-op without a mesh."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(logical, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


def sharding_for(logical: Sequence[Optional[str]], shape) -> Optional[NamedSharding]:
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return None
    return NamedSharding(rules.mesh, rules.spec(logical, shape=shape))


def tree_shardings(mesh: Mesh, tree_logical, tree_shapes, fsdp: bool = False):
    """Build a NamedSharding pytree from parallel pytrees of logical axes
    and shapes (ShapeDtypeStructs)."""
    rules = Rules(mesh, fsdp=fsdp)

    def one(logical, sds):
        return NamedSharding(mesh, rules.spec(logical, shape=sds.shape))

    return jax.tree.map(one, tree_logical, tree_shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
