"""Core neural-network layers, pure JAX, shared by every architecture.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every layer is
a pure function ``layer(params, x, cfg, ...) -> y``.  Sharding is applied
through :mod:`repro.models.partitioning` logical-axis annotations, which
are no-ops outside a mesh context.

Param creation goes through :class:`ParamSpec` so the same specification
yields (a) real initialised arrays for tests/smoke runs, (b)
``ShapeDtypeStruct`` trees for the multi-pod dry-run, and (c) logical-axes
trees for ``in_shardings``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .partitioning import constrain

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | value
    scale: float = 1.0          # stddev multiplier for "normal"
    value: float = 0.0          # for init == "value"
    dtype: str = "float32"

    def initialise(self, key) -> jnp.ndarray:
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        if self.init == "value":
            return jnp.full(self.shape, self.value, dt)
        # fan-in scaled normal
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dt)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


def init_tree(specs, rng) -> Dict[str, Any]:
    """Materialise a pytree of ParamSpec into real arrays."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda s: isinstance(s, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.initialise(k) for s, k in zip(leaves, keys)])


def abstract_tree(specs):
    return jax.tree.map(lambda s: s.sds(), specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


# ---------------------------------------------------------------------------
# Basic ops
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    ang = ang[..., None, :]                                 # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, sliding window, KV cache)
# ---------------------------------------------------------------------------

# int8 KV quantization: symmetric, per-token/head dynamic scale (the
# scale tensor adds 4/head_dim ~= 3% overhead and keeps relative error
# ~0.4%, preserving decode logits — see test_kv_quant_decode)


def quantize_kv(x):
    """x: (..., D) -> (int8 values, f32 scales (..., 1))."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127)
    return q.astype(jnp.int8), s


def dequantize_kv(q, s, dtype):
    return (q.astype(jnp.float32) * s).astype(dtype)


def attention_specs(cfg, d_in=None, prefix="") -> Dict[str, ParamSpec]:
    d = d_in or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = max(h, cfg.head_pad)
    dt = cfg.dtype
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None), dtype=dt),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", None), dtype=dt),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", None), dtype=dt),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed"), dtype=dt),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        specs["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return specs


def _sdpa(q, k, v, mask, scale, n_real_heads=None):
    """q: (B,Sq,H,D) k,v: (B,Sk,KV,D). GQA by repeating KV heads via a
    gather (shards cleanly over the "heads" model axis when divisible,
    degrades to replicated attention otherwise — see partitioning.Rules).
    ``n_real_heads``: unpadded head count — the kv-group mapping of the
    real heads must not shift when heads are padded (head_pad)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    if H != KV:
        hr = n_real_heads or H
        kmap = jnp.clip(jnp.arange(H) * KV // hr, 0, KV - 1)
        k = jnp.take(k, kmap, axis=2)
        v = jnp.take(v, kmap, axis=2)
        k = constrain(k, ("batch", None, "heads", None))
        v = constrain(v, ("batch", None, "heads", None))
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def _sdpa_q_chunked(q, k, v, scale, chunk, *, prefix_len=0, window=0,
                    n_real_heads=None):
    """Causal attention with the query axis processed in lax.map chunks.

    Caps the materialized score tile at (B, H, chunk, Sk) — the pure-JAX
    stand-in for the Pallas flash kernels on long-sequence prefill (the
    kernels do not lower through the GSPMD CPU dry-run).  Each chunk is
    rematted so the backward pass never holds all score tiles at once."""
    B, S, H, D = q.shape
    nc = S // chunk
    qs = jnp.moveaxis(q.reshape(B, nc, chunk, H, D), 1, 0)

    def one(args):
        ic, qq = args
        qi = ic * chunk + jnp.arange(chunk)[:, None]
        ki = jnp.arange(S)[None, :]
        m = ki <= qi
        if prefix_len:
            m = jnp.logical_or(m, (ki < prefix_len)[None, :])
        if window:
            m = jnp.logical_and(m, ki > qi - window)
        return _sdpa(qq, k, v, m[None, None], scale,
                     n_real_heads=n_real_heads)

    out = jax.lax.map(jax.checkpoint(one),
                      (jnp.arange(nc), qs))          # (nc, B, chunk, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)


def attention(params, x, cfg, *, positions, cache=None, cache_index=None,
              kv_override=None, window: int = 0, causal: bool = True,
              prefix_len: int = 0):
    """Unified attention.

    Modes:
      * full prefill (cache=None): causal (or bidirectional) self-attention
        over ``x``; returns (out, (k, v)) so callers may keep the KV cache.
      * decode (cache=(k,v) of length S, cache_index given): ``x`` holds one
        (or few) new tokens; new K/V are written into the cache ring buffer
        at ``cache_index % S`` and attention runs over the full cache.
      * cross-attention (kv_override=(k,v)): no cache write, no causal mask.

    ``prefix_len`` > 0 marks the leading tokens as a bidirectional prefix
    (used for ranking-with-cache: candidate items attend to the whole
    cached user-behaviour prefix).
    """
    B, S, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hp = max(h, cfg.head_pad)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        if kv_override is None:
            k = rms_norm(k, params["k_norm"])
    if cfg.rope_theta and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    scale = 1.0 / np.sqrt(hd)

    if cache is not None:
        quant = len(cache) == 4  # (k_i8, v_i8, k_scale, v_scale)
        if quant:
            ck, cv, cks, cvs = cache
        else:
            ck, cv = cache  # (B, Sc, KV, D)
        Sc = ck.shape[1]
        if cache_index is not None:
            slot = (cache_index % Sc).astype(jnp.int32)
            ohb = jax.nn.one_hot(slot, Sc, dtype=jnp.bool_)  # (B, Sc)
            if quant:
                kw, kws = quantize_kv(k)
                vw, vws = quantize_kv(v)
                ck = jnp.where(ohb[:, :, None, None], kw, ck)
                cv = jnp.where(ohb[:, :, None, None], vw, cv)
                cks = jnp.where(ohb[:, :, None, None], kws, cks)
                cvs = jnp.where(ohb[:, :, None, None], vws, cvs)
            else:
                ck = jnp.where(ohb[:, :, None, None], k, ck)
                cv = jnp.where(ohb[:, :, None, None], v, cv)
        if quant:
            k_all = dequantize_kv(ck, cks, k.dtype)
            v_all = dequantize_kv(cv, cvs, v.dtype)
        else:
            k_all, v_all = ck, cv
        mask = None  # ring cache: every live entry is attendable
        out = _sdpa(q, k_all, v_all, mask, scale, n_real_heads=h)
        new_cache = (ck, cv, cks, cvs) if quant else (ck, cv)
    else:
        qc = cfg.attn_q_chunk
        if qc and S >= 4 * qc and S % qc == 0 and causal:
            out = _sdpa_q_chunked(q, k, v, scale, qc,
                                  prefix_len=prefix_len, window=window,
                                  n_real_heads=h)
        else:
            mask = None
            if causal:
                qi = jnp.arange(S)[:, None]
                ki = jnp.arange(S)[None, :]
                m = ki <= qi
                if prefix_len:
                    m = jnp.logical_or(m, (ki < prefix_len)[None, :])
                if window:
                    m = jnp.logical_and(m, ki > qi - window)
                mask = m[None, None, :, :]
            out = _sdpa(q, k, v, mask, scale, n_real_heads=h)
        new_cache = (k, v)
    if hp > h:
        # padded heads (Megatron-style head padding for awkward head
        # counts): masked out of the output, receive no gradient
        hmask = (jnp.arange(hp) < h).astype(out.dtype)
        out = out * hmask[None, None, :, None]
    out = constrain(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# Feed-forward (GLU or plain)
# ---------------------------------------------------------------------------


def ffn_specs(cfg, d_ff=None, prefix="") -> Dict[str, ParamSpec]:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, cfg.dtype
    if cfg.glu:
        return {
            "wi": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
            "wg": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
            "wo": ParamSpec((f, d), ("ff", "embed"), dtype=dt),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "ff"), dtype=dt),
        "wo": ParamSpec((f, d), ("ff", "embed"), dtype=dt),
    }


def ffn(params, x, cfg):
    act = _act(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    if cfg.glu:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"])
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, ("batch", "seq", "ff"))
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    return constrain(y, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, vocab: int):
    """logits: (..., Vp) possibly vocab-padded; labels int (...)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab:
        # elementwise iota mask (NOT .at[vocab:].set, which is a dynamic-
        # update-slice misaligned with the vocab sharding and forces a
        # full-logits all-gather under GSPMD)
        vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(vid < vocab, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold
