"""Model configuration for every architecture family the framework serves.

A single ``ModelConfig`` dataclass describes dense decoders, MoE decoders,
SSM (Mamba2 / RWKV6) stacks, hybrid SSM+attention stacks, encoder-decoder
(audio) backbones and VLM decoders.  ``family`` selects the block wiring;
the remaining fields parameterize the blocks.

Conventions
-----------
* ``head_dim`` defaults to ``d_model // n_heads`` unless set explicitly.
* ``vocab_padded`` rounds the vocabulary up to a multiple of 256 so the
  embedding/output projection shards evenly over a 16-way model axis
  (Megatron-style vocab padding; logits beyond ``vocab`` are masked).
* MoE: ``n_experts`` routed experts with per-expert FFN width
  ``d_expert``; ``n_shared_experts`` always-on shared experts; ``top_k``
  routing.  ``d_ff`` is the dense-FFN width used by non-MoE layers (or by
  the shared expert when ``d_expert`` differs).
* SSM (mamba2): ``ssm_state`` is the per-head state width N; d_inner =
  ``ssm_expand * d_model``; ``ssm_head_dim`` the value head dim P.
* Hybrid (zamba2): ``attn_every`` inserts one shared-weight GQA block
  after every ``attn_every`` mamba blocks.
* enc-dec: ``n_enc_layers`` encoder layers; decoder uses ``n_layers``.
* VLM / audio: ``n_frontend_tokens`` precomputed patch/frame embeddings
  prepended to the token sequence (the frontend itself is stubbed per the
  assignment: ``input_specs`` provides embeddings of the right shape).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm_mamba2 | ssm_rwkv6 | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # ffn
    d_ff: int = 0
    act: str = "silu"
    glu: bool = True
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    router_aux_coef: float = 0.01
    # ssm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # hybrid
    attn_every: int = 0
    # encoder-decoder
    n_enc_layers: int = 0
    # modality frontend stub (audio frames / vision patches)
    n_frontend_tokens: int = 0
    # long-context: sliding-window size used for the long_500k decode shape
    # (dense archs only run long_500k when this is non-zero)
    sliding_window: int = 0
    # pad attention heads up to this count (Megatron-style padding so an
    # awkward head count shards over the model axis; padded heads are
    # masked out of the output and receive no gradient)
    head_pad: int = 0
    # q-chunked attention: chunk the query axis in lax.map blocks of this
    # size when S >= 4*chunk (caps the materialized score tile; the real
    # TPU path uses the Pallas flash kernels instead)
    attn_q_chunk: int = 0
    # route HSTU attention through the Pallas kernels (TPU serving path;
    # on CPU they run in interpret mode — slow but bit-checked)
    use_flash_kernels: bool = False
    # int8 KV cache (symmetric, static scale): halves the decode-path
    # HBM stream — the dominant roofline term of every decode shape
    kv_quant: bool = False
    # numerics
    dtype: str = "bfloat16"
    # HSTU-style pointwise attention (generative recommendation backbone)
    hstu: bool = False
    # ranking head: number of task-tower outputs (GR ranking); 0 = LM head
    n_tasks: int = 0
    source: str = ""  # citation

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family in ("ssm_mamba2", "ssm_rwkv6")

    @property
    def supports_long_context(self) -> bool:
        """True if the arch can run the 500k-token decode shape."""
        return (
            self.family in ("ssm_mamba2", "ssm_rwkv6", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step (none assigned here)."""
        return True

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_padded
        n = 0
        n += v * d  # embedding
        n += v * d  # unembedding (untied)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            hd = self.head_dim
            per_layer += d * self.n_heads * hd  # q
            per_layer += 2 * d * self.n_kv_heads * hd  # k, v
            per_layer += self.n_heads * hd * d  # o
            per_layer += 2 * d  # norms
            if self.family == "moe":
                de = self.d_expert
                per_layer += self.n_experts * (3 * d * de)
                per_layer += self.n_shared_experts * (3 * d * de)
                per_layer += d * self.n_experts  # router
            else:
                mult = 3 if self.glu else 2
                per_layer += mult * d * self.d_ff
            n += self.n_layers * per_layer
            if self.family == "encdec":
                # encoder layers + cross-attention in decoder
                enc = self.n_enc_layers * (
                    4 * d * self.n_heads * hd + 3 * d * self.d_ff + 2 * d
                )
                xattn = self.n_layers * (
                    d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d + d
                )
                n += enc + xattn
        elif self.family == "ssm_mamba2":
            di, ns = self.d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * ns * 1 + self.n_ssm_heads)  # in_proj approx
            per_layer += d * 2 * di + di * d + 3 * d * self.d_ff + 2 * d
            n += self.n_layers * per_layer
        elif self.family == "ssm_rwkv6":
            mult = 3 if self.glu else 2
            per_layer = 5 * d * d + 2 * d * 64 + mult * d * self.d_ff + 2 * d
            n += self.n_layers * per_layer
        elif self.family == "hybrid":
            di = self.d_inner
            per_layer = d * 2 * di + di * d + 3 * d * self.d_ff + 2 * d
            n += self.n_layers * per_layer
            hd = self.head_dim
            n += 4 * d * self.n_heads * hd  # one shared attention block
        if self.hstu:
            # HSTU blocks: f1 produces U,V,Q,K (4x), f2 back
            n = v * d + self.n_layers * (4 * d * d + d * d + 2 * d)
            if self.n_tasks:
                n += d * 4 * d + 4 * d * self.n_tasks
        return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
