"""State-space sequence layers: Mamba2 (SSD) and RWKV6 (Finch).

TPU adaptation notes (see DESIGN.md):
  * Mamba2 uses the chunked SSD formulation — intra-chunk work is plain
    batched matmul (MXU-friendly) and only the inter-chunk recurrence is a
    ``lax.scan`` over ``L/chunk`` steps.  This replaces the CUDA selective
    -scan kernel with a matmul-dominant algorithm natural to the MXU.
  * RWKV6 keeps a time-step ``lax.scan`` for the prefill path (the decode
    path is O(1) per token) — its recurrence is rank-1 per step and does
    not benefit from chunking as much; heads shard over the model axis.

Both expose a recurrent state usable as the "KV cache" analogue for the
decode input shapes: Mamba2 state (B, H, N, P); RWKV6 state (B, H, N, P)
plus the token-shift buffer.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, rms_norm
from .partitioning import constrain

MAMBA_CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_specs(cfg) -> Dict[str, ParamSpec]:
    d, di, N, H, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.n_ssm_heads, cfg.ssm_head_dim)
    dt = cfg.dtype
    return {
        # in_proj -> [z(di), x(di), B(N), C(N), dt(H)]
        "w_in": ParamSpec((d, 2 * di + 2 * N + H), ("embed", "ff"), dtype=dt),
        "conv": ParamSpec((cfg.ssm_conv, di + 2 * N), (None, "ff"),
                          init="normal", scale=0.5, dtype=dt),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="value", value=0.0),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((di,), ("ff",), init="ones"),
        "w_out": ParamSpec((di, d), ("ff", "embed"), dtype=dt),
    }


def _mamba_split(params, u, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    proj = jnp.einsum("bld,de->ble", u, params["w_in"])
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, weight, state=None):
    """Depthwise causal conv along time. state: (B, K-1, C) history."""
    K = weight.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * weight[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out), new_state


def mamba2_forward(params, u, cfg, state=None):
    """u: (B, L, d).  Returns (y, (ssm_state, conv_state)).

    ``state``: optional (ssm_state (B,H,N,P), conv_state (B,K-1,C)) to
    continue from (prefix-cache / decode continuation).
    """
    B, L, d = u.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    Q = min(MAMBA_CHUNK, L)
    ssm0 = state[0] if state is not None else jnp.zeros(
        (B, H, N, P), jnp.float32)
    conv0 = state[1] if state is not None else None

    z, xBC, dtr = _mamba_split(params, u, cfg)
    xBC, conv_state = _causal_conv(xBC, params["conv"], conv0)
    x, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    x = x.reshape(B, L, H, P)
    x = constrain(x, ("batch", "seq", "ssm_heads", None))
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"])           # (B, L, H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (H,) negative
    la = dt * A                                         # log-decay <= 0

    nc = max(L // Q, 1)
    xc = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    lac = la.reshape(B, nc, Q, H)
    cum = jnp.cumsum(lac, axis=2)                       # (B,nc,Q,H)

    # intra-chunk (matmul-dominant)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)      # (B,nc,Q,Q)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # t - t'
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(dec), 0.0)                    # (B,nc,Q,Q,H)
    Mx = M * scores[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", Mx, xc)

    # chunk summaries -> inter-chunk scan
    tail = cum[:, :, -1:, :] - cum                      # decay to chunk end
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                     Bc, jnp.exp(tail) * dtc, xc)       # (B,nc,H,N,P)
    a_tot = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    def step(S, inp):
        Sc, at = inp
        S_out = S
        S = at[..., None, None] * S + Sc
        return S, S_out

    Sc_t = jnp.moveaxis(S_c, 1, 0)
    at_t = jnp.moveaxis(a_tot, 1, 0)
    S_final, S_prev = jax.lax.scan(step, ssm0, (Sc_t, at_t))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                 # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, jnp.exp(cum), S_prev)
    y = (y_intra + y_inter).reshape(B, L, H, P)
    y = y + params["D"][None, None, :, None] * x.reshape(B, L, H, P)
    y = y.reshape(B, L, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bld,de->ble", y, params["w_out"])
    return constrain(out, ("batch", "seq", "embed")), (S_final, conv_state)


def mamba2_decode(params, u, cfg, state):
    """Single-token step. u: (B, 1, d); state from mamba2_forward."""
    B = u.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    ssm, conv = state
    z, xBC, dtr = _mamba_split(params, u, cfg)
    xBC, conv = _causal_conv(xBC, params["conv"], conv)
    x, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    x = x.reshape(B, H, P).astype(jnp.float32)
    Bm = Bm[:, 0].astype(jnp.float32)                   # (B,N)
    Cm = Cm[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                 # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm, dt, x)
    ssm = a[..., None, None] * ssm + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, ssm) + params["D"][None, :, None] * x
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bld,de->ble", y, params["w_out"])
    return out, (ssm, conv)


def mamba2_state_specs(cfg, batch: int):
    B, H, N, P = batch, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    C = cfg.d_inner + 2 * cfg.ssm_state
    return (
        (jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
         ("batch", "ssm_heads", None, None)),
        (jax.ShapeDtypeStruct((B, cfg.ssm_conv - 1, C), jnp.dtype(cfg.dtype)),
         ("batch", None, "ff")),
    )


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay
# ---------------------------------------------------------------------------

RWKV_HEAD = 64
RWKV_LORA = 64


def rwkv6_specs(cfg) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.dtype
    H = d // RWKV_HEAD
    return {
        "mu": ParamSpec((5, d), (None, "embed"), init="value", value=0.5),
        "w0": ParamSpec((d,), ("embed",), init="value", value=-4.0),
        "w_lora_a": ParamSpec((d, RWKV_LORA), ("embed", None), dtype=dt),
        "w_lora_b": ParamSpec((RWKV_LORA, d), (None, "embed"),
                              init="zeros", dtype=dt),
        "wr": ParamSpec((d, d), ("embed", "heads"), dtype=dt),
        "wk": ParamSpec((d, d), ("embed", "heads"), dtype=dt),
        "wv": ParamSpec((d, d), ("embed", "heads"), dtype=dt),
        "wg": ParamSpec((d, d), ("embed", "heads"), dtype=dt),
        "u": ParamSpec((H, RWKV_HEAD), ("rwkv_heads", None),
                       init="value", value=0.5),
        "ln_out": ParamSpec((d,), ("embed",), init="ones"),
        "w_out": ParamSpec((d, d), ("heads", "embed"), dtype=dt),
    }


def _rwkv_mix(params, x, x_prev):
    """Token-shift mixing for r,k,v,w,g. x: (B,L,d); x_prev (B,1,d)."""
    xx = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = params["mu"]                                   # (5, d)
    mixed = x[None] + (xx - x)[None] * mu[:, None, None, :]
    return mixed.astype(x.dtype)  # (5, B, L, d) order: r,k,v,w,g


def _rwkv_wkv_scan(r, k, v, w, u, state):
    """r,k,v: (B,L,H,N); w: (B,L,H,N) decay in (0,1); state (B,H,N,N)."""
    def step(S, inp):
        rt, kt, vt, wt = inp                            # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]        # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), S                    # (B,L,H,N)


def rwkv6_forward(params, x, cfg, state=None):
    """x: (B,L,d). state: (wkv (B,H,N,N) f32, shift (B,1,d)).

    Returns (y, new_state)."""
    B, L, d = x.shape
    H, N = d // RWKV_HEAD, RWKV_HEAD
    if state is None:
        wkv0 = jnp.zeros((B, H, N, N), jnp.float32)
        shift0 = jnp.zeros((B, 1, d), x.dtype)
    else:
        wkv0, shift0 = state
    xr, xk, xv, xw, xg = _rwkv_mix(params, x, shift0)
    r = jnp.einsum("bld,de->ble", xr, params["wr"]).reshape(B, L, H, N)
    k = jnp.einsum("bld,de->ble", xk, params["wk"]).reshape(B, L, H, N)
    v = jnp.einsum("bld,de->ble", xv, params["wv"]).reshape(B, L, H, N)
    g = jax.nn.silu(jnp.einsum("bld,de->ble", xg, params["wg"]))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    lora = jnp.einsum("blr,rd->bld",
                      jnp.tanh(jnp.einsum("bld,dr->blr", xw,
                                          params["w_lora_a"])),
                      params["w_lora_b"])
    wlog = params["w0"][None, None, :] + lora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, L, H, N)
    r = constrain(r, ("batch", "seq", "rwkv_heads", None))
    y, wkv = _rwkv_wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w,
                            params["u"].astype(jnp.float32), wkv0)
    y = y.reshape(B, L, d).astype(x.dtype)
    y = rms_norm(y, params["ln_out"]) * g
    out = jnp.einsum("bld,de->ble", y, params["w_out"])
    new_shift = x[:, -1:, :]
    return constrain(out, ("batch", "seq", "embed")), (wkv, new_shift)


def rwkv6_state_specs(cfg, batch: int):
    d = cfg.d_model
    H, N = d // RWKV_HEAD, RWKV_HEAD
    return (
        (jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
         ("batch", "rwkv_heads", None, None)),
        (jax.ShapeDtypeStruct((batch, 1, d), jnp.dtype(cfg.dtype)),
         ("batch", None, "embed")),
    )
