"""Encoder-decoder backbone (SeamlessM4T-v2 style, audio frontend stubbed).

The speech frontend (mel filterbank + conformer feature extractor) is a
stub per the assignment: ``batch_specs`` exposes precomputed frame
embeddings (B, F, d_model).  This module implements the transformer
encoder over those frames and the causal text decoder with
self-attention KV cache + cross-attention to the encoder output.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .arch import (BaseModel, _embed, _logits, ce_loss, embed_specs,
                   stack_specs)
from .config import InputShape
from .layers import (ParamSpec, attention, attention_specs, cross_entropy,
                     ffn, ffn_specs, rms_norm)
from .partitioning import constrain


class EncDecModel(BaseModel):
    def enc_block_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            "attn": attention_specs(cfg),
            "ffn": ffn_specs(cfg),
        }

    def dec_block_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        return {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "lnx": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            "attn": attention_specs(cfg),
            "xattn": attention_specs(cfg),
            "ffn": ffn_specs(cfg),
        }

    def param_specs(self):
        cfg = self.cfg
        specs = dict(embed_specs(cfg))
        specs["encoder"] = stack_specs(self.enc_block_specs(),
                                       cfg.n_enc_layers)
        specs["decoder"] = stack_specs(self.dec_block_specs(), cfg.n_layers)
        specs["enc_norm"] = ParamSpec((cfg.d_model,), ("embed",),
                                      init="ones")
        return specs

    # --- encoder ---------------------------------------------------------
    def encode(self, params, frames, remat=False):
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])[None, :]

        def body(x, pl):
            h, _ = attention(pl["attn"], rms_norm(x, pl["ln1"]), cfg,
                             positions=positions, causal=False)
            x = x + h
            x = x + ffn(pl["ffn"], rms_norm(x, pl["ln2"]), cfg)
            return x, None

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, frames, params["encoder"])
        return rms_norm(x, params["enc_norm"])

    # --- decoder ---------------------------------------------------------
    def _dec_run(self, params, x, positions, enc=None, self_cache=None,
                 cross_kv=None, cache_index=None, remat=False):
        cfg = self.cfg

        def body(xc, per_layer):
            pl, sc, xkv = per_layer
            h, kvc = attention(pl["attn"], rms_norm(xc, pl["ln1"]), cfg,
                               positions=positions, cache=sc,
                               cache_index=cache_index)
            xc = xc + h
            if xkv is None:  # compute cross-KV from encoder output
                xn = rms_norm(xc, pl["lnx"])
                ek = jnp.einsum("bfd,dhk->bfhk", enc, pl["xattn"]["wk"])
                ev = jnp.einsum("bfd,dhk->bfhk", enc, pl["xattn"]["wv"])
            else:
                xn = rms_norm(xc, pl["lnx"])
                ek, ev = xkv
            h, _ = attention(pl["xattn"], xn, cfg, positions=positions,
                             kv_override=(ek, ev), causal=False)
            xc = xc + h
            xc = xc + ffn(pl["ffn"], rms_norm(xc, pl["ln2"]), cfg)
            return xc, (kvc, (ek, ev))

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, (kv, xkv) = jax.lax.scan(
            body, x, (params["decoder"], self_cache, cross_kv))
        return x, kv, xkv

    # --- protocol ----------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], remat=True)
        x = _embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, _ = self._dec_run(params, x, positions, enc=enc, remat=True)
        ce = ce_loss(params, x, batch["labels"], cfg)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        enc = self.encode(params, batch["frames"])
        x = _embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        x, kv, xkv = self._dec_run(params, x, positions, enc=enc)
        return _logits(params, x[:, -1:]), {"self": kv, "cross": xkv}

    def decode_step(self, params, cache, batch):
        x = _embed(params, batch["token"])
        positions = batch["pos"][:, None]
        x, kv, xkv = self._dec_run(params, x, positions,
                                   self_cache=cache["self"],
                                   cross_kv=cache["cross"],
                                   cache_index=batch["pos"])
        return _logits(params, x), {"self": kv, "cross": xkv}

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        F = cfg.n_frontend_tokens
        kv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim),
            jnp.dtype(cfg.dtype))
        xkv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, F, cfg.n_kv_heads, cfg.head_dim),
            jnp.dtype(cfg.dtype))
        seq_ax = "kv_seq" if (batch == 1 and seq_len >= 65536) else None
        kv_axes = ("layers", "batch", seq_ax, "kv_heads", None)
        xkv_axes = ("layers", "batch", None, "kv_heads", None)
        return ({"self": (kv, kv), "cross": (xkv, xkv)},
                {"self": (kv_axes, kv_axes), "cross": (xkv_axes, xkv_axes)})

    def init_cache(self, batch: int, seq_len: int):
        sds, _ = self.cache_specs(batch, seq_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def batch_specs(self, shape: InputShape):
        specs = super().batch_specs(shape)
        cfg = self.cfg
        if shape.kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return specs

    def batch_axes(self, shape: InputShape):
        axes = super().batch_axes(shape)
        if shape.kind != "decode":
            axes["frames"] = ("batch", "frames", "embed")
        return axes
