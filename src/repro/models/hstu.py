"""HSTU generative-recommendation backbone (Zhai et al., 2024) — the GR
model family served by RelayGR.

HSTU replaces softmax attention with a pointwise aggregated attention:

    U, V, Q, K = split(SiLU(f1(norm(x))))
    A          = SiLU(Q K^T / sqrt(d)) / n        (no softmax)
    y          = x + f2(norm(A V) * U)

The per-layer (K, V) tensors of the *user-behaviour prefix* are exactly
the cache object psi(u) RelayGR pre-infers and relays across pipeline
stages.  ``rank_with_cache`` consumes psi: incremental tokens
(short-term behaviours + cross features) attend causally, candidate
items attend to prefix+incremental but NOT to each other (independent
scoring), and a task tower maps each item position to a score.

This file is the pure-JAX reference; the Pallas kernels in
``repro.kernels`` (hstu_attn, prefix_rank_attn) implement the same
contractions with VMEM tiling for TPU.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .arch import (BaseModel, _embed, _logits, ce_loss, embed_specs,
                   stack_specs)
from .config import InputShape, ModelConfig
from .layers import ParamSpec, apply_rope, cross_entropy, rms_norm
from .partitioning import constrain


def hstu_block_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, hd, dt = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.dtype
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "uvqk": ParamSpec((d, 4, h, hd), ("embed", None, "heads", None),
                          dtype=dt),
        "ln_attn": ParamSpec((h * hd,), ("heads",), init="ones"),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed"), dtype=dt),
    }


def hstu_attention(q, k, v, mask, n_total: float):
    """Pointwise SiLU attention (no softmax). q,k,v: (B,S,H,D)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    a = jax.nn.silu(logits) / n_total
    if mask is not None:
        a = jnp.where(mask, a, 0.0)
    return jnp.einsum("bhqs,bshd->bqhd", a.astype(v.dtype), v)


def rank_mask(n_prefix: int, n_incr: int, n_items: int):
    """Attention mask for ranking-with-cache.

    Queries: [incr tokens | item tokens]; keys: [prefix | incr | items].
    Incr tokens: causal over prefix+incr.  Items: see prefix+incr+self
    only (candidate independence)."""
    Sq = n_incr + n_items
    Sk = n_prefix + n_incr + n_items
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    causal = ki <= (qi + n_prefix)
    is_item_q = qi >= n_incr
    is_item_k = ki >= n_prefix + n_incr
    self_key = ki == (qi + n_prefix)
    items_ok = jnp.where(is_item_q, (~is_item_k) | self_key, True)
    return (causal & items_ok)[None, None, :, :]


class HSTUModel(BaseModel):
    """Implements both the LM-style protocol (for dry-run parity) and the
    RelayGR prefix/rank protocol used by the serving engine."""

    def block_specs(self):
        return hstu_block_specs(self.cfg)

    def param_specs(self):
        cfg = self.cfg
        specs = dict(embed_specs(cfg))
        specs["layers"] = stack_specs(self.block_specs(), cfg.n_layers)
        if cfg.n_tasks:
            d = cfg.d_model
            specs["task_tower"] = {
                "w1": ParamSpec((d, 4 * d), ("embed", "ff"), dtype=cfg.dtype),
                "w2": ParamSpec((4 * d, cfg.n_tasks), ("ff", None),
                                dtype=cfg.dtype),
            }
        return specs

    # --- core block -------------------------------------------------------
    def _block(self, p, x, positions, mask, cache=None, n_total=None):
        cfg = self.cfg
        h, hd = cfg.n_heads, cfg.head_dim
        B, S, d = x.shape
        xn = rms_norm(x, p["ln"])
        uvqk = jax.nn.silu(jnp.einsum("bsd,dfhk->bsfhk", xn, p["uvqk"]))
        u, v, q, k = [uvqk[:, :, i] for i in range(4)]
        if cfg.rope_theta:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if cache is not None:
            pk, pv = cache  # cached prefix (B, P, H, D)
            k_all = jnp.concatenate([pk, k], axis=1)
            v_all = jnp.concatenate([pv, v], axis=1)
        else:
            k_all, v_all = k, v
        nt = n_total or k_all.shape[1]
        if cfg.use_flash_kernels and mask is None and cache is not None:
            from repro.kernels import ops as kops
            av = kops.hstu_attention(q, k_all, v_all, n_total=nt)
        else:
            av = hstu_attention(q, k_all, v_all, mask, nt)
        av = rms_norm(av.reshape(B, S, h * hd),
                      p["ln_attn"]).reshape(B, S, h, hd)
        gated = av * u
        y = jnp.einsum("bshk,hkd->bsd", gated, p["wo"])
        return x + constrain(y, ("batch", "seq", "embed")), (k, v)

    def _run(self, params, x, positions, mask, cache=None, remat=False):
        def body(xc, per_layer):
            pl, cl = per_layer
            y, kv = self._block(pl, xc, positions, mask, cache=cl)
            return y, kv

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, (params["layers"], cache))

    # --- LM-style protocol (dry-run parity with other archs) ---------------
    def loss(self, params, batch):
        cfg = self.cfg
        x = _embed(params, batch["tokens"])
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        mask = jnp.tril(jnp.ones((S, S), bool))[None, None]
        x, _ = self._run(params, x, positions, mask, remat=True)
        ce = ce_loss(params, x, batch["labels"], cfg)
        return ce, {"ce": ce}

    def prefill(self, params, batch):
        """Pre-inference: compute psi = per-layer (K, V) of the prefix."""
        x = _embed(params, batch["tokens"])
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        mask = jnp.tril(jnp.ones((S, S), bool))[None, None]
        x, kv = self._run(params, x, positions, mask)
        return _logits(params, x[:, -1:]), kv

    def decode_step(self, params, cache, batch):
        x = _embed(params, batch["token"])
        positions = batch["pos"][:, None]
        x, _ = self._run(params, x, positions, None, cache=cache)
        return _logits(params, x), cache

    # --- RelayGR rank protocol ---------------------------------------------
    def rank_with_cache(self, params, cache, incr_tokens, item_tokens):
        """Score candidate items reusing the cached prefix psi.

        cache: per-layer (K, V) stacked (L, B, P, H, D) — or None for the
        fallback full-inference path (then incr_tokens must contain the
        full behaviour sequence).
        Returns (scores (B, n_items, n_tasks), updated hidden).
        """
        cfg = self.cfg
        B, n_incr = incr_tokens.shape
        n_items = item_tokens.shape[1]
        n_prefix = 0 if cache is None else cache[0].shape[2]
        x = _embed(params, jnp.concatenate([incr_tokens, item_tokens],
                                           axis=1))
        positions = (n_prefix + jnp.arange(n_incr + n_items))[None, :]
        mask = rank_mask(n_prefix, n_incr, n_items)
        x, _ = self._run(params, x, positions, mask, cache=cache)
        items_h = x[:, n_incr:]
        tw = params["task_tower"]
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", items_h, tw["w1"]))
        scores = jnp.einsum("bsf,ft->bst", h, tw["w2"])
        return scores

    def full_rank(self, params, prefix_tokens, incr_tokens, item_tokens):
        """Baseline: full inference with the long prefix on the critical
        path (no cache)."""
        _, kv = self.prefill(params, {"tokens": prefix_tokens})
        return self.rank_with_cache(params, kv, incr_tokens, item_tokens)

    def cache_specs(self, batch: int, seq_len: int):
        cfg = self.cfg
        kv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, seq_len, cfg.n_heads, cfg.head_dim),
            jnp.dtype(cfg.dtype))
        seq_ax = "kv_seq" if (batch == 1 and seq_len >= 65536) else None
        axes = ("layers", "batch", seq_ax, "heads", None)
        return (kv, kv), (axes, axes)

    def init_cache(self, batch: int, seq_len: int):
        sds, _ = self.cache_specs(batch, seq_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)

    def kv_bytes(self, seq_len: int) -> int:
        """psi footprint per user — drives trigger admission control."""
        cfg = self.cfg
        sds, _ = self.cache_specs(1, seq_len)
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(sds))
