"""Model registry: family name -> model class; config id -> ModelConfig."""

from __future__ import annotations

import importlib
from typing import Dict

from .arch import HybridModel, SSMModel, TransformerModel
from .config import ModelConfig
from .encdec import EncDecModel
from .hstu import HSTUModel

_FAMILY = {
    "dense": TransformerModel,
    "moe": TransformerModel,
    "vlm": TransformerModel,
    "ssm_mamba2": SSMModel,
    "ssm_rwkv6": SSMModel,
    "hybrid": HybridModel,
    "encdec": EncDecModel,
    "hstu": HSTUModel,
}

ARCH_IDS = [
    "starcoder2_15b", "zamba2_1p2b", "qwen3_4b", "starcoder2_7b",
    "rwkv6_1p6b", "seamless_m4t_large_v2", "yi_9b", "internvl2_2b",
    "deepseek_moe_16b", "dbrx_132b", "hstu_gr",
]

# CLI aliases matching the assignment spelling
ALIASES = {
    "starcoder2-15b": "starcoder2_15b",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "yi-9b": "yi_9b",
    "internvl2-2b": "internvl2_2b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "dbrx-132b": "dbrx_132b",
    "hstu-gr": "hstu_gr",
}


def build_model(cfg: ModelConfig):
    family = "hstu" if cfg.hstu else cfg.family
    return _FAMILY[family](cfg)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    arch_id = ALIASES.get(arch_id, arch_id).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.smoke_config() if smoke else mod.config()


def get_model(arch_id: str, smoke: bool = False):
    return build_model(get_config(arch_id, smoke=smoke))
