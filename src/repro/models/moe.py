"""Mixture-of-Experts layer with expert-parallel dispatch.

Design (TPU-native adaptation, see DESIGN.md):
  * Router runs as ordinary sharded jnp ops (tokens sharded over the
    batch/data axes).
  * Expert FFNs are sharded over the "model" mesh axis.  Inside a
    ``shard_map`` over that axis, every device sees its local slice of the
    expert weights and the full (per-data-shard) token set, computes a
    capacity-bounded scatter/gather dispatch for *its* experts only, and a
    final ``psum`` over the model axis combines the top-k contributions.
    This keeps compiled FLOPs equal to ``C x E x ffn`` (capacity-bounded,
    honest for the roofline) instead of the dense all-experts-all-tokens
    fallback which would inflate compute by E/k.
  * Dropped tokens (capacity overflow) contribute zero, matching
    Switch/GShard semantics; capacity_factor=2 keeps drops rare.

Without a mesh (unit tests, smoke configs) the same dispatch runs locally
with ``E_local == E`` — one code path, exercised everywhere.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamSpec, _act
from .partitioning import current_rules

try:  # jax >= 0.6 promotes shard_map
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as P

CAPACITY_FACTOR = 2.0


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    d, e, f, dt = cfg.d_model, cfg.n_experts, cfg.d_expert, cfg.dtype
    specs = {
        "router": ParamSpec((d, e), ("embed", None), dtype="float32"),
        "wi": ParamSpec((e, d, f), ("experts", "embed", None), dtype=dt),
        "wg": ParamSpec((e, d, f), ("experts", "embed", None), dtype=dt),
        "wo": ParamSpec((e, f, d), ("experts", None, "embed"), dtype=dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.d_expert
        specs.update({
            "shared_wi": ParamSpec((d, fs), ("embed", "ff"), dtype=dt),
            "shared_wg": ParamSpec((d, fs), ("embed", "ff"), dtype=dt),
            "shared_wo": ParamSpec((fs, d), ("ff", "embed"), dtype=dt),
        })
    return specs


def _expert_compute(x, gates, eidx, wi, wg, wo, first_expert, capacity, act):
    """Capacity-bounded dispatch/FFN/combine for a local slice of experts.

    x: (T, d); gates/eidx: (T, k); wi/wg/wo: (E_local, ...) local slices.
    """
    T, d = x.shape
    k = eidx.shape[-1]
    E_local = wi.shape[0]
    e = eidx.reshape(T * k) - first_expert
    g = gates.reshape(T * k)
    local = (e >= 0) & (e < E_local)
    el = jnp.where(local, e, 0)
    # position of each slot within its expert's capacity buffer
    oh = jax.nn.one_hot(el, E_local, dtype=jnp.int32) * local[:, None]
    pos = (jnp.cumsum(oh, axis=0) - oh)  # exclusive cumsum
    pos = jnp.take_along_axis(pos, el[:, None], axis=1)[:, 0]
    keep = local & (pos < capacity)
    el_c = jnp.where(keep, el, 0)
    pos_c = jnp.where(keep, pos, capacity)  # OOB index -> dropped below
    tok = jnp.arange(T * k) // k
    xk = x[tok] * keep[:, None].astype(x.dtype)
    x_disp = jnp.zeros((E_local, capacity, d), x.dtype)
    x_disp = x_disp.at[el_c, pos_c].add(xk, mode="drop")
    # per-expert GLU FFN
    hi = jnp.einsum("ecd,edf->ecf", x_disp, wi)
    hg = jnp.einsum("ecd,edf->ecf", x_disp, wg)
    h = act(hg) * hi
    y_e = jnp.einsum("ecf,efd->ecd", h, wo)
    # gather back to slots and combine
    pad = jnp.zeros((E_local, 1, d), y_e.dtype)
    y_pad = jnp.concatenate([y_e, pad], axis=1)
    y_slot = y_pad[el_c, pos_c] * (g * keep)[:, None].astype(y_e.dtype)
    return y_slot.reshape(T, k, d).sum(axis=1)


def moe_ffn(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_load_balance_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    act = _act(cfg.act)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jax.nn.one_hot(eidx, E).sum(axis=2).mean(axis=(0, 1))   # (E,)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce) / k

    rules = current_rules()
    mesh = rules.mesh if rules else None
    model_n = rules.axis_size("model") if rules else 1
    ep = mesh is not None and model_n > 1 and E % model_n == 0

    x2 = x.reshape(B * S, d)
    g2 = gates.reshape(B * S, k).astype(x.dtype)
    i2 = eidx.reshape(B * S, k)

    if not ep:
        cap = int(B * S * k / E * CAPACITY_FACTOR) + 1
        y = _expert_compute(x2, g2, i2, params["wi"], params["wg"],
                            params["wo"], 0, cap, act)
        return y.reshape(B, S, d), aux

    # ----- expert-parallel path: shard_map over the "model" axis -----
    E_local = E // model_n
    bspec = rules.spec(("batch",), shape=(B * S,))
    bd = bspec[0]
    cap = None  # computed inside from the local token count

    def ep_fn(xl, gl, il, wi, wg, wo):
        Tl = xl.shape[0]
        first = jax.lax.axis_index("model") * E_local
        capacity = int(Tl * k / E * CAPACITY_FACTOR) + 1
        y = _expert_compute(xl, gl, il, wi, wg, wo, first, capacity, act)
        return jax.lax.psum(y, axis_name="model")

    y = _shard_map(
        ep_fn, mesh=mesh,
        in_specs=(P(bd, None), P(bd, None), P(bd, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(bd, None),
        check_vma=False,
    )(x2, g2, i2, params["wi"], params["wg"], params["wo"])
    return y.reshape(B, S, d), aux


def shared_expert_ffn(params, x, cfg):
    act = _act(cfg.act)
    hi = jnp.einsum("bsd,df->bsf", x, params["shared_wi"])
    hg = jnp.einsum("bsd,df->bsf", x, params["shared_wg"])
    return jnp.einsum("bsf,fd->bsd", act(hg) * hi, params["shared_wo"])
