"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) record, derive the three roofline terms:

  compute    = FLOPs_per_chip / peak_FLOPs          [s]
  memory     = HBM_traffic_per_chip / HBM_bw        [s]
  collective = collective_bytes_per_chip / link_bw  [s]

Sources and caveats (documented in EXPERIMENTS.md §Roofline):
  * FLOPs: jaxpr-level dot/conv count (exact scan trip accounting;
    XLA's CPU cost_analysis counts while bodies once), divided by chips.
    Replication waste (e.g. 36-head attention on a 16-way model axis)
    is additionally estimated via the compiled per-chip cost_analysis
    where available.
  * HBM traffic proxy: argument + output + 2x temp bytes from
    compiled.memory_analysis() — compiled-real per-chip sizes; temp is
    touched at least twice (produce+consume).
  * collective bytes: summed output-operand sizes of all all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute ops in
    the partitioned HLO (per-chip module).

Also reports MODEL_FLOPS = 6*N*D (train) or 2*N_active*tokens (serve)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import glob
import json
import os
from pathlib import Path
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import INPUT_SHAPES, get_config

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def active_param_count(cfg) -> int:
    """Parameters touched per token (MoE: shared + top_k experts only)."""
    n = cfg.param_count()
    if cfg.n_experts:
        d, de = cfg.d_model, cfg.d_expert
        routed_all = cfg.n_layers * cfg.n_experts * 3 * d * de
        routed_active = cfg.n_layers * cfg.top_k * 3 * d * de
        n = n - routed_all + routed_active
    return n


def model_flops(cfg, shape) -> float:
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token/seq


def analyse_record(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec.get("n_chips", 256)
    flops_chip = rec["jaxpr_flops_global"] / chips
    mem = rec.get("memory", {})
    traffic = (mem.get("argument_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               + 2 * mem.get("temp_size_in_bytes", 0))
    coll = rec.get("collectives", {}).get("total_bytes", 0)

    t_comp = flops_chip / PEAK_FLOPS_BF16
    t_mem = traffic / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": rec["jaxpr_flops_global"],
        "useful_ratio": round(mf / max(rec["jaxpr_flops_global"], 1), 3),
        "hbm_bytes_chip": traffic,
        "coll_bytes_chip": coll,
        "roofline_bound_s": round(max(terms.values()), 6),
        "fsdp": rec.get("fsdp", False),
    }
    # per-chip compiled flops (scan-undercounted; used to estimate
    # replication waste on archs whose heads cannot shard)
    cost_flops = rec.get("cost", {}).get("flops")
    if cost_flops:
        out["xla_flops_chip_scanbody"] = cost_flops
    return out


def load(tag: str = "baseline", mesh: str = "16x16") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(str(ARTIFACTS / f"{tag}__*.json"))):
        rec = json.loads(Path(path).read_text())
        if rec.get("status") != "ok":
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        rows.append(analyse_record(rec))
    return rows


def table(rows: List[dict]) -> str:
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio")
    lines = [" | ".join(hdr), " | ".join("---" for _ in hdr)]
    for r in rows:
        lines.append(" | ".join(str(r[h]) for h in hdr))
    return "\n".join(lines)


def main():
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    rows = load(tag=tag)
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_bound_s")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']},"
              f"{r['memory_s']},{r['collective_s']},{r['dominant']},"
              f"{r['useful_ratio']},{r['roofline_bound_s']}")
    out = ARTIFACTS.parent / f"roofline_{tag}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out}", flush=True)


if __name__ == "__main__":
    main()
