"""Committed capacity artifacts: ``BENCH_capacity.json`` + CSV curves.

``headline`` assembles the machine-readable matrix result — per-cell
knee QPS and latency–throughput curves under a ``meta`` block that
records full *workload provenance* (seed, population, skew/arrival
axes, sim duration), so ``benchmarks/check_regression.py`` can refuse
to diff capacity headlines produced under mismatched workloads.

``curves_csv`` flattens every cell's curve into one plottable CSV
(committed next to the JSON), and ``render`` prints the human-readable
knee table.
"""

from __future__ import annotations

import io
import json
from typing import Dict, Tuple

from .matrix import CURVE_FIELDS, MatrixSpec

#: meta fields two capacity headlines must share before a knee diff is
#: meaningful (sim duration and quick-ness are intentionally NOT here:
#: the CI smoke diffs its short coarse run against the committed full
#: run, under widened tolerances)
PROVENANCE_FIELDS = ("seed", "population", "slo_ms")


def headline(cells: Dict[str, Dict], spec: MatrixSpec,
             isolation: Dict = None) -> Dict:
    populations = sorted({w.population for w in spec.workloads})
    meta = {
        "seed": spec.seed,
        "population": populations[0] if len(populations) == 1
        else populations,
        "slo_ms": spec.slo_ms,
        "sim_s": spec.duration_s,
        "quick": spec.quick,
        "arrivals": sorted({w.arrival for w in spec.workloads}),
        "skews": sorted({w.skew for w in spec.workloads}),
        "matrix": spec.to_dict(),
    }
    out = {"meta": meta, "cells": cells}
    if isolation is not None:
        out["isolation"] = isolation
    return out


def curves_csv(cells: Dict[str, Dict]) -> str:
    """Flatten every cell curve into one CSV (one row per measured
    operating point) for plotting latency–throughput curves."""
    out = io.StringIO()
    cols = ("cell", "mode", "L", "workload", "knee_qps") + CURVE_FIELDS
    print(",".join(cols), file=out)
    for name, cell in cells.items():
        lead = [name, cell["mode"], str(cell["L"]), cell["workload_name"],
                str(cell["knee_qps"])]
        for row in cell["curve"]:
            vals = lead + [str(row.get(f, "")) for f in CURVE_FIELDS]
            print(",".join(vals), file=out)
    return out.getvalue()


def render(cells: Dict[str, Dict]) -> str:
    """Human-readable knee table (printed after a run)."""
    out = io.StringIO()
    width = max((len(n) for n in cells), default=4) + 2
    print(f"{'cell'.ljust(width)} {'knee_qps':>9} {'goodput':>8} "
          f"{'p99@knee':>9} {'hbm_hit':>8} {'miss':>6}", file=out)
    for name, cell in cells.items():
        at_knee = next((r for r in reversed(cell["curve"])
                        if r["offered_qps"] <= cell["knee_qps"] + 1e-9),
                       cell["curve"][-1] if cell["curve"] else {})
        print(f"{name.ljust(width)} {cell['knee_qps']:>9.0f} "
              f"{cell['knee_goodput_qps']:>8.0f} "
              f"{at_knee.get('p99_ms', float('nan')):>9.1f} "
              f"{at_knee.get('hbm_hit', float('nan')):>8.3f} "
              f"{at_knee.get('miss', float('nan')):>6.3f}", file=out)
    return out.getvalue()


def write(path: str, cells: Dict[str, Dict], spec: MatrixSpec,
          isolation: Dict = None) -> Tuple[str, str]:
    """Write ``BENCH_capacity.json`` and its sibling CSV; returns both
    paths."""
    data = headline(cells, spec, isolation)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    csv_path = path.rsplit(".", 1)[0] + "_curves.csv"
    with open(csv_path, "w") as f:
        f.write(curves_csv(cells))
    return path, csv_path
