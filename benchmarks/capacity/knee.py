"""Knee-finding: the largest offered QPS whose measured run still meets
an SLO criterion — the x-location of the latency–throughput curve's
knee, and the scalar every mode is gated on in CI.

The search is shared by the capacity matrix and the legacy figure
harness (``benchmarks.figures._max_qps`` is a thin wrapper).  It
replaces the old hard ``hi=1200`` bisection cap with *geometric
upper-bound expansion*: the upper probe doubles until the criterion
fails (or an explicit ``hard_cap`` backstop is reached), so future
throughput gains are never silently clipped at a constant that was
sized for last year's runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

#: absolute backstop for the geometric expansion — only a guard against
#: a degenerate criterion that never fails (e.g. an empty stream); any
#: real deployment saturates long before this
HARD_CAP_QPS = 1e6


@dataclasses.dataclass
class KneeResult:
    """Outcome of one knee search."""
    best: float                 # criterion-key value at the knee (0 if none)
    knee_qps: float             # largest offered QPS that passed
    probes: List[Tuple[float, bool, Dict]]  # (offered_qps, ok, summary)
    hard_cap: float = HARD_CAP_QPS

    @property
    def capped(self) -> bool:
        """True iff the expansion hit the ``hard_cap`` backstop while
        still passing — the measured knee is a lower bound, not a
        knee."""
        return bool(self.probes) and self.probes[-1][1] \
            and self.probes[-1][0] >= self.hard_cap


def find_knee(measure: Callable[[float], Dict],
              criterion: Callable[[Dict], bool], *,
              lo: float = 5.0, hi: Optional[float] = None,
              key: str = "goodput_qps", coarse: bool = False,
              hard_cap: float = HARD_CAP_QPS) -> KneeResult:
    """Bisect for the largest offered QPS meeting ``criterion``.

    ``measure(qps)`` runs one experiment and returns its summary dict;
    ``criterion(summary)`` decides pass/fail; the returned ``best`` is
    ``summary[key]`` at the highest passing probe (goodput under the
    pipeline-SLO criterion, raw throughput under stage-budget ones).

    ``hi`` seeds the upper probe (default ``32·lo``).  A passing upper
    probe is *expanded geometrically* (doubled) until the criterion
    fails, so the search brackets the knee wherever it is;  ``coarse``
    widens the bisection tolerance (used by --quick CI smoke runs).
    """
    best, knee = 0.0, 0.0
    probes: List[Tuple[float, bool, Dict]] = []

    def probe(q: float) -> bool:
        nonlocal best, knee
        s = measure(q)
        ok = bool(criterion(s))
        probes.append((q, ok, s))
        if ok and q > knee:
            best, knee = float(s.get(key, 0.0)), q
        return ok

    hi = float(hi) if hi is not None else max(32.0 * lo, 160.0)
    # geometric upper-bound expansion: double until the criterion fails
    while hi < hard_cap and probe(hi):
        lo, hi = hi, min(hi * 2.0, hard_cap)
    if hi >= hard_cap and (not probes or probes[-1][1]):
        # degenerate: even the backstop passes — report it as capped
        probe(hard_cap)
        return KneeResult(best=best, knee_qps=knee, probes=probes,
                          hard_cap=hard_cap)
    if not any(ok for _, ok, _ in probes):
        # the seed upper probe failed outright: ground the bracket by
        # probing lo itself — otherwise bisection narrows toward an
        # UNVERIFIED lower bound and can report knee_qps=0/best=0 with
        # no evidence that lo fails (every mid probe may fail while lo
        # would have passed)
        if not probe(lo):
            return KneeResult(best=best, knee_qps=knee, probes=probes,
                              hard_cap=hard_cap)
    slack = 0.30 if coarse else 0.08
    while hi - lo > max(4.0, lo * slack):
        mid = (lo + hi) / 2.0
        if probe(mid):
            lo = mid
        else:
            hi = mid
    return KneeResult(best=best, knee_qps=knee, probes=probes,
                      hard_cap=hard_cap)
