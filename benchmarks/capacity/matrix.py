"""Declarative capacity matrix: {mode × sequence length × workload ×
offered QPS} → per-cell latency distributions + per-cell knee.

benchalot-style: a ``MatrixSpec`` (buildable from a plain dict / JSON
file) declares the axes; ``run_matrix`` executes every cell through the
discrete-event ``ClusterSim`` (the real relay state machines under the
calibrated cost model), finds each cell's SLO knee with the shared
geometric-expansion knee-finder, and measures a latency–throughput
curve at knee-anchored offered-QPS fractions.

The mode configurations (``mode_config``) and the single-point runner
(``run_point``) are the machinery formerly buried in
``benchmarks/figures.py`` (``_cfg`` / ``_run``); figures re-exports
them, so the paper-figure harness and the capacity harness can never
drift apart.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.costmodel import GRCostModel
from repro.core.runtime import ClusterConfig, RelayConfig, relay_config
from repro.core.trigger import TriggerConfig
from repro.models import get_config
from repro.serving.simulator import ClusterSim

from .knee import KneeResult, find_knee
from .workload import WorkloadSpec, fixed_stream

HSTU = get_config("hstu_gr")
COST = GRCostModel(HSTU)

N_INST = 5          # 4 active + 1 idle opposite-pool instance
SIM_S = 12.0
SLO_MS = 135.0

#: every serving mode the harness understands (the BENCH_relay set)
ALL_MODES = ("baseline", "relay", "relay_dram", "relay_batched",
             "relay_paged", "relay_devpool", "relay_segments",
             "relay_multihost", "relay_disagg", "relay_cold",
             "relay_tenants")


def mode_config(mode: str, L: int, *, hosts: Optional[int] = None,
                prefill_hosts: Optional[int] = None) -> RelayConfig:
    """mode: baseline | relay | relay_dram | relay_batched | relay_paged
    | relay_devpool | relay_segments | relay_multihost | relay_disagg
    | relay_cold | relay_tenants

    ``relay_batched`` is the ``relay`` deployment with continuous
    micro-batching switched on (same trigger/cache -> equal hit rates);
    the throughput delta is pure batching.  ``relay_paged`` is
    ``relay_batched`` over the paged HBM window (64-token pages): same
    trigger and byte budget, psi block-granular — hit rates must match
    ``relay_batched`` with slo_qps within tolerance (page-rounded load
    times are the only modelled difference at page-aligned L).
    ``relay_devpool`` is ``relay_paged`` with the device-resident page
    pool: inserts/reloads scatter only fresh pages and rank launches
    pass the pool by reference instead of re-shipping it.  In the
    simulator the pool data plane is byte-free, so the trace — hit
    rates, latency, slo_qps — must be IDENTICAL to ``relay_paged``
    (the h2d win is a live-serving property, gated by the CI smoke's
    ``launch_reships == 0`` assert and measured by
    ``benchmarks/calibrate.py --h2d``); the row exists so the sim
    config path stays exercised and regression-gated.
    ``relay_segments`` is ``relay_paged`` with beyond-prefix reuse
    (RcLLM): the stream attaches per-user candidate-independent
    ``seg_lens``, the side path caches those interior segments
    alongside the prefix as page-aligned spans, and a cache hit ranks
    only the truly fresh incr tokens — the reused-token fraction per
    hit must EXCEED ``relay_paged`` at equal-or-better slo_qps.
    ``relay_multihost`` is ``relay_batched`` striped over two hosts
    (owner-map -> per-host ring routing, per-host DRAM tiers): affinity
    hit rates must stay within 2% of the single-host deployment — the
    two-level rendezvous changes WHERE producer and consumer meet, not
    whether they do.  ``relay_disagg`` is ``relay_multihost`` with the
    pre-infer side path disaggregated onto dedicated prefill hosts:
    psi ships cross-host to its owner over the NIC fabric, so hit
    rates must stay within 2% of ``relay_multihost`` (the shipment
    lands inside the retrieval slack at the reference point) while the
    ranking hosts' slots are freed of prefill compute.  The prefill
    tier is provisioned with headroom (two hosts x 20 slots: the point
    of disaggregation is that the side path never contends, so pre
    groups stay shallow and the NIC hop still beats the retrieval
    slack at the admission ceiling) and two NIC links, so neither
    compute nor the fabric caps admission below the colocated
    600/s pool ceiling (Eq. 3b).  ``relay_cold`` is ``relay_segments``
    with the full memory hierarchy under it: a bounded DRAM expander
    (4 GB, ~120 psi — small enough that skewed traffic overflows it)
    plus a 500 GB host-local cold tier (SSD / remote psi store) that
    absorbs DRAM evictions as demotions and revives cold-resident
    users through an async cold->DRAM->HBM promotion priced on the
    cold bandwidth class — tail users that every DRAM-only mode
    re-prefills come back as cache hits.  ``relay_tenants`` is
    ``relay_batched`` serving TWO tenants off the one fleet: every
    memory tier is split into per-tenant byte quotas (a tenant can
    only evict its own entries), admission layers per-tenant token
    buckets under the instance/pool split, and ``run_point`` stamps
    each request's tenant as ``user_id % 2`` — a pure function of the
    id, so the arrival trace is identical to ``relay_batched``'s and
    any hit-rate delta is the partition itself.

    ``hosts`` / ``prefill_hosts`` override the mode's default topology
    (the capacity matrix's hosts axis); ``None`` keeps the default.
    """
    if mode not in ALL_MODES:
        raise ValueError(f"unknown mode {mode!r}; known: {ALL_MODES}")
    relay = mode != "baseline"
    r2 = 0.8 if relay else 0.2   # 4 active instances either way
    hbm_cache = 4e9
    batched = mode in ("relay_batched", "relay_paged", "relay_devpool",
                       "relay_segments", "relay_multihost",
                       "relay_disagg", "relay_cold", "relay_tenants")
    paged = mode in ("relay_paged", "relay_devpool", "relay_segments",
                     "relay_cold")
    multihost = mode in ("relay_multihost", "relay_disagg")
    if hosts is None:
        hosts = 2 if multihost else 1
    if prefill_hosts is None:
        prefill_hosts = 2 if mode == "relay_disagg" else 0
    return relay_config(
        trigger=TriggerConfig(n_instances=N_INST, r2=r2,
                              kv_p99_len=max(L, 1024),
                              hbm_bytes=hbm_cache / 0.5, r1=0.5,
                              t_life_s=0.5),
        cluster=ClusterConfig(
            relay_enabled=relay,
            dram_budget_bytes=(500e9 if mode == "relay_dram"
                               else 4e9 if mode == "relay_cold" else 0.0),
            cold_budget_bytes=500e9 if mode == "relay_cold" else 0.0,
            hbm_cache_bytes=hbm_cache,
            max_batch=8 if batched else 0,
            batch_wait_ms=2.0,
            hosts=hosts,
            prefill_hosts=prefill_hosts,
            prefill_m_slots=20 if prefill_hosts else 0,
            page_tokens=64 if paged else 0,
            device_pool=mode == "relay_devpool",
            segments=mode in ("relay_segments", "relay_cold"),
            tenants=2 if mode == "relay_tenants" else 1),
    )


# ---------------------------------------------------------------------------
# single-point runners
# ---------------------------------------------------------------------------


def _distribution(sim: ClusterSim, summary: Dict) -> Dict:
    """Extend a runtime summary with the full latency distribution the
    capacity curves commit (the runtime's summary stops at p50/p99)."""
    recs = sim.records
    if not recs:
        return dict(summary)
    e2e = np.array([r.e2e_ms for r in recs])
    out = dict(summary)
    out.update(
        mean_ms=float(e2e.mean()),
        p90_ms=float(np.percentile(e2e, 90)),
        p95_ms=float(np.percentile(e2e, 95)),
        max_ms=float(e2e.max()))
    return out


def run_point(mode, L, qps, *, cost=None, dur=SIM_S, seed=0, refresh=None,
              pipeline=None, n_items=512, workload: Optional[WorkloadSpec]
              = None, hosts=None, prefill_hosts=None,
              distribution: bool = False) -> Dict:
    """Run ONE (mode, L, workload, offered-qps) operating point through
    the cluster simulator and return its summary (formerly
    ``figures._run``).  ``workload=None`` keeps the legacy uniform
    ``fixed_stream``; ``distribution=True`` adds the extended
    percentiles the capacity curves commit."""
    cost = cost or COST
    refresh = (0.5 if mode in ("relay_dram", "relay_cold") else 0.0) \
        if refresh is None else refresh
    cfg = mode_config(mode, L, hosts=hosts, prefill_hosts=prefill_hosts)
    if pipeline is not None:
        cfg = dataclasses.replace(cfg, pipeline=pipeline)
    if workload is None:
        arr = fixed_stream(L, qps, dur, refresh=refresh, seed=seed,
                           dim=cost.cfg.d_model, n_items=n_items)
    else:
        arr = workload.stream(L, qps, dur, seed=seed,
                              dim=cost.cfg.d_model, n_items=n_items)
    if cfg.cluster.segments:
        # attach per-user candidate-independent seg_lens from the
        # dedicated hash RNG — the arrival/popularity draws above are
        # untouched, so relay_segments sees the exact trace relay_paged
        # sees, plus segment annotations
        from repro.data.synthetic import segment_lens
        arr = ((t, dataclasses.replace(
            m, seg_lens=segment_lens(m.user_id, m.incr_len)))
            for t, m in arr)
    if cfg.cluster.tenants > 1:
        # stamp each request's tenant as a pure function of the user id
        # (no RNG draw): relay_tenants replays the exact trace the
        # untenanted modes see, so any metric delta is the partition
        n_t = int(cfg.cluster.tenants)
        arr = ((t, dataclasses.replace(m, tenant=m.user_id % n_t))
               for t, m in arr)
    sim = ClusterSim(cfg, cost)
    s = sim.run(arr)
    return _distribution(sim, s) if distribution else s


def meets_slo(s: Dict, slo_ms: float = SLO_MS) -> bool:
    """Pipeline-SLO criterion: P99 within the end-to-end SLO and
    (essentially) every request completed."""
    return s.get("n", 0) > 0 and s["p99_ms"] <= slo_ms \
        and s["success_rate"] >= 0.999


# ---------------------------------------------------------------------------
# the matrix
# ---------------------------------------------------------------------------


DEFAULT_WORKLOADS = (
    WorkloadSpec(skew=0.0, arrival="poisson"),     # legacy reference
    WorkloadSpec(skew=1.1, arrival="poisson"),     # head-skewed traffic
    WorkloadSpec(skew=1.1, arrival="mmpp"),        # skewed AND bursty
)


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """Declarative capacity matrix (see capacity/README.md for the JSON
    schema).  Cells are the cartesian product of ``modes`` ×
    ``lengths`` × ``workloads`` × ``hosts_axis``; the offered-QPS axis
    of each cell is knee-anchored (``curve_fractions`` × the cell's
    measured knee), so every mode's curve brackets ITS OWN saturation
    point instead of sharing one global sweep."""
    modes: Tuple[str, ...] = ("baseline", "relay", "relay_batched",
                              "relay_disagg", "relay_cold")
    lengths: Tuple[int, ...] = (2048, 4096)
    workloads: Tuple[WorkloadSpec, ...] = DEFAULT_WORKLOADS
    curve_fractions: Tuple[float, ...] = (0.5, 0.75, 0.9, 1.0, 1.15)
    hosts_axis: Tuple[Optional[int], ...] = (None,)   # None -> mode default
    duration_s: float = SIM_S
    slo_ms: float = SLO_MS
    seed: int = 0
    quick: bool = False

    @classmethod
    def quick_spec(cls) -> "MatrixSpec":
        """The CI smoke matrix: 3 cells, short sims, coarse knees."""
        return cls(modes=("baseline", "relay_batched", "relay_disagg"),
                   lengths=(2048,),
                   workloads=(WorkloadSpec(skew=1.1, arrival="poisson"),),
                   curve_fractions=(0.7, 1.0),
                   duration_s=4.0, quick=True)

    def to_dict(self) -> Dict:
        return {"modes": list(self.modes),
                "lengths": list(self.lengths),
                "workloads": [w.to_dict() for w in self.workloads],
                "curve_fractions": list(self.curve_fractions),
                "hosts_axis": list(self.hosts_axis),
                "duration_s": self.duration_s,
                "slo_ms": self.slo_ms,
                "seed": self.seed,
                "quick": self.quick}

    @classmethod
    def from_dict(cls, d: Dict) -> "MatrixSpec":
        kw: Dict = {}
        for f in ("duration_s", "slo_ms", "seed", "quick"):
            if f in d:
                kw[f] = d[f]
        if "modes" in d:
            kw["modes"] = tuple(d["modes"])
        if "lengths" in d:
            kw["lengths"] = tuple(int(x) for x in d["lengths"])
        if "workloads" in d:
            kw["workloads"] = tuple(WorkloadSpec.from_dict(w)
                                    for w in d["workloads"])
        if "curve_fractions" in d:
            kw["curve_fractions"] = tuple(float(x)
                                          for x in d["curve_fractions"])
        if "hosts_axis" in d:
            kw["hosts_axis"] = tuple(None if x is None else int(x)
                                     for x in d["hosts_axis"])
        return cls(**kw)

    def cell_keys(self) -> List[Tuple]:
        return list(itertools.product(self.modes, self.lengths,
                                      self.workloads, self.hosts_axis))


def cell_name(mode: str, L: int, wl: WorkloadSpec,
              hosts: Optional[int] = None) -> str:
    name = f"{mode}/L{L}/{wl.name}"
    return name if hosts is None else f"{name}/hosts{hosts}"


CURVE_FIELDS = ("offered_qps", "n", "p50_ms", "p90_ms", "p95_ms", "p99_ms",
                "mean_ms", "max_ms", "rank_p99_ms", "pre_p99_ms",
                "load_p99_ms", "throughput_qps", "goodput_qps",
                "success_rate", "hbm_hit", "dram_hit", "cold_hit", "miss",
                "special_util", "reused_frac")


def _curve_row(qps: float, s: Dict) -> Dict:
    row = {"offered_qps": round(float(qps), 2)}
    for f in CURVE_FIELDS[1:]:
        v = s.get(f)
        if v is not None:
            row[f] = round(float(v), 4)
    return row


def run_cell(mode: str, L: int, wl: WorkloadSpec, *,
             hosts: Optional[int] = None, fractions=(0.5, 0.75, 0.9,
                                                     1.0, 1.15),
             dur: float = SIM_S, slo_ms: float = SLO_MS, seed: int = 0,
             cost: Optional[GRCostModel] = None, coarse: bool = False
             ) -> Dict:
    """One matrix cell: knee search (geometric expansion + bisection)
    followed by the latency–throughput curve at knee-anchored offered
    QPS.  Returns the committed cell record."""
    def measure(q: float) -> Dict:
        return run_point(mode, L, q, workload=wl, dur=dur, seed=seed,
                         cost=cost, hosts=hosts)

    res: KneeResult = find_knee(
        measure, lambda s: meets_slo(s, slo_ms), coarse=coarse)
    knee = res.knee_qps
    curve = []
    for frac in fractions:
        q = max(frac * knee, 1.0)
        s = run_point(mode, L, q, workload=wl, dur=dur, seed=seed,
                      cost=cost, hosts=hosts, distribution=True)
        curve.append(_curve_row(q, s))
    return {
        "mode": mode, "L": L, "workload": wl.to_dict(),
        "workload_name": wl.name,
        "head_share_top100": round(wl.head_share(100), 4),
        "tail_share_top100": round(wl.tail_share(100), 4),
        "hosts": hosts,
        "knee_qps": round(knee, 1),
        "knee_goodput_qps": round(res.best, 1),
        "knee_capped": res.capped,
        "knee_probes": len(res.probes),
        "curve": curve,
    }


def run_matrix(spec: MatrixSpec, *, cost: Optional[GRCostModel] = None,
               progress: Optional[Callable[[str], None]] = None
               ) -> Dict[str, Dict]:
    """Execute every cell of the matrix; returns ``{cell_name: record}``
    ordered by the spec's axes."""
    cells: Dict[str, Dict] = {}
    keys = spec.cell_keys()
    for i, (mode, L, wl, hosts) in enumerate(keys):
        name = cell_name(mode, L, wl, hosts)
        if progress is not None:
            progress(f"[{i + 1}/{len(keys)}] {name}")
        cells[name] = run_cell(
            mode, L, wl, hosts=hosts, fractions=spec.curve_fractions,
            dur=spec.duration_s, slo_ms=spec.slo_ms, seed=spec.seed,
            cost=cost, coarse=spec.quick)
        if progress is not None:
            c = cells[name]
            progress(f"    knee={c['knee_qps']:.0f} qps "
                     f"(goodput {c['knee_goodput_qps']:.0f}/s, "
                     f"{c['knee_probes']} probes)")
    return cells


# ---------------------------------------------------------------------------
# two-tenant burst isolation (the relay_tenants acceptance cell)
# ---------------------------------------------------------------------------

#: tenant B's mean offered load during the isolation bench's MMPP
#: burst — sized well inside the fleet's headroom so the bench measures
#: the PARTITION (quotas + per-tenant buckets), not raw compute
#: contention, which no cache policy can hide
ISO_BURST_QPS = 10.0


def run_tenant_point(qps_a: float, *, burst_qps: float = 0.0,
                     L: int = 2048, dur: float = SIM_S, seed: int = 0,
                     cost: Optional[GRCostModel] = None) -> Dict:
    """One two-tenant operating point: tenant A (skewed Poisson) at
    ``qps_a`` next to tenant B (skewed MMPP burst) at mean
    ``burst_qps`` (0 = solo A), through the ``relay_tenants``
    deployment.  Returns tenant A's ``tenant_summary`` slice — the
    isolation bench compares that slice solo vs under B's burst.

    The config is IDENTICAL in both runs (two-tenant quotas either
    way); only B's traffic changes, and ``multi_tenant_stream`` seeds
    each tenant's RNG independently, so A's arrival/popularity draws
    are bit-identical with or without the burst."""
    from repro.data.synthetic import multi_tenant_stream
    cost = cost or COST
    cfg = mode_config("relay_tenants", L)
    mixes = [dict(L=L, qps=qps_a, skew=1.1, arrival="poisson",
                  dim=cost.cfg.d_model, n_items=512)]
    if burst_qps > 0:
        mixes.append(dict(L=L, qps=burst_qps, skew=1.1, arrival="mmpp",
                          dim=cost.cfg.d_model, n_items=512))
    sim = ClusterSim(cfg, cost)
    sim.run(multi_tenant_stream(mixes, dur, seed=seed))
    s = sim.runtime.tenant_summary().get(0, {"n": 0})
    if s.get("n"):
        s["goodput_qps"] = s["n"] * s["success_rate"] / dur
    return s


def isolation_cell(*, burst_qps: float = ISO_BURST_QPS, L: int = 2048,
                   dur: float = SIM_S, slo_ms: float = SLO_MS,
                   seed: int = 0, cost: Optional[GRCostModel] = None,
                   coarse: bool = False) -> Dict:
    """The committed burst-isolation record (``BENCH_capacity.json``'s
    ``isolation`` block): tenant A's SLO knee and hit rate, measured
    solo and again while tenant B runs an MMPP burst on the same
    fleet.  The regression gate requires the burst to move neither —
    per-tenant byte quotas keep B out of A's cache, and the per-tenant
    admission bucket keeps B's surge out of A's pool-token share."""
    def knee_of(burst: float) -> KneeResult:
        return find_knee(
            lambda q: run_tenant_point(q, burst_qps=burst, L=L, dur=dur,
                                       seed=seed, cost=cost),
            lambda s: meets_slo(s, slo_ms), coarse=coarse)

    solo_knee = knee_of(0.0)
    burst_knee = knee_of(burst_qps)
    # hit-rate comparison at one fixed operating point safely below the
    # solo knee (knee noise must not move the reference load)
    q_ref = max(0.75 * solo_knee.knee_qps, 1.0)
    solo = run_tenant_point(q_ref, burst_qps=0.0, L=L, dur=dur,
                            seed=seed, cost=cost)
    burst = run_tenant_point(q_ref, burst_qps=burst_qps, L=L, dur=dur,
                             seed=seed, cost=cost)

    def slice_rec(knee: KneeResult, s: Dict) -> Dict:
        return {"knee_qps": round(knee.knee_qps, 1),
                "n": int(s.get("n", 0)),
                "hit_rate": round(s.get("hit_rate", 0.0), 4),
                "hbm_hit": round(s.get("hbm_hit", 0.0), 4),
                "miss": round(s.get("miss", 0.0), 4),
                "p99_ms": round(s.get("p99_ms", 0.0), 3)}

    return {
        "mode": "relay_tenants", "L": L, "tenants": 2,
        "tenant_a": {"skew": 1.1, "arrival": "poisson"},
        "tenant_b": {"skew": 1.1, "arrival": "mmpp",
                     "qps": burst_qps},
        "ref_qps": round(q_ref, 1),
        "solo": slice_rec(solo_knee, solo),
        "burst": slice_rec(burst_knee, burst),
        "hit_delta": round(burst.get("hit_rate", 0.0)
                           - solo.get("hit_rate", 0.0), 4),
        "knee_ratio": round(burst_knee.knee_qps
                            / max(solo_knee.knee_qps, 1e-9), 4),
    }
