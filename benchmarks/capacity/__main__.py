"""Capacity harness entry point.

    PYTHONPATH=src python -m benchmarks.capacity [--quick] [--out PATH]
                                                 [--matrix FILE] ...

Runs the declarative capacity matrix ({mode × L × workload × offered
QPS}) through the cluster simulator, finds each cell's SLO knee, and
writes the committed artifacts: ``BENCH_capacity.json`` and
``BENCH_capacity_curves.csv``.  ``--quick`` runs the 3-cell CI smoke
matrix (short sims, coarse knees — its ``meta.quick`` flag is recorded
so the regression gate refuses a quick file as a committed reference).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from .matrix import MatrixSpec, isolation_cell, run_matrix
from .report import render, write


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.capacity",
        description="trace-realistic capacity matrix: knee-finding + "
                    "latency-throughput curves per serving mode")
    ap.add_argument("--quick", action="store_true",
                    help="3-cell CI smoke matrix (short sims, coarse "
                         "knee bisection)")
    ap.add_argument("--out", default="BENCH_capacity.json",
                    help="output JSON path (CSV curves written next to "
                         "it; '' disables writing)")
    ap.add_argument("--matrix", default=None,
                    help="JSON file with a declarative MatrixSpec "
                         "(see benchmarks/capacity/README.md)")
    ap.add_argument("--modes", default=None,
                    help="comma-separated mode override")
    ap.add_argument("--lengths", default=None,
                    help="comma-separated sequence-length override")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--duration", type=float, default=None,
                    help="per-point sim duration (s)")
    ap.add_argument("--no-isolation", action="store_true",
                    help="skip the two-tenant burst-isolation cell "
                         "(relay_tenants acceptance; run by default)")
    args = ap.parse_args(argv)

    if args.matrix:
        with open(args.matrix) as f:
            spec = MatrixSpec.from_dict(json.load(f))
        if args.quick:
            spec = _replace(spec, duration_s=4.0, quick=True)
    else:
        spec = MatrixSpec.quick_spec() if args.quick else MatrixSpec()
    if args.modes:
        spec = _replace(spec, modes=tuple(args.modes.split(",")))
    if args.lengths:
        spec = _replace(spec, lengths=tuple(
            int(x) for x in args.lengths.split(",")))
    if args.seed is not None:
        spec = _replace(spec, seed=args.seed)
    if args.duration is not None:
        spec = _replace(spec, duration_s=args.duration)

    t0 = time.time()
    cells = run_matrix(spec, progress=lambda m: print(m, file=sys.stderr))
    print(render(cells), end="")
    iso = None
    if not args.no_isolation:
        print("isolation: tenant A solo vs tenant B MMPP burst ...",
              file=sys.stderr)
        iso = isolation_cell(dur=spec.duration_s, slo_ms=spec.slo_ms,
                             seed=spec.seed, coarse=spec.quick)
        print(f"isolation: A knee {iso['solo']['knee_qps']:.0f} -> "
              f"{iso['burst']['knee_qps']:.0f} qps under burst, "
              f"hit_rate delta {iso['hit_delta']:+.4f}")
    if args.out:
        json_path, csv_path = write(args.out, cells, spec, iso)
        print(f"# wrote {json_path} + {csv_path} "
              f"in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


def _replace(spec: MatrixSpec, **kw) -> MatrixSpec:
    import dataclasses
    return dataclasses.replace(spec, **kw)


if __name__ == "__main__":
    raise SystemExit(main())
