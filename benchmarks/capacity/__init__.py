"""Capacity harness: trace-realistic workload matrix, knee-finding, and
committed latency–throughput curves.

The measurement substrate the ROADMAP's open items prove themselves on:
a declarative matrix runner over {offered QPS × sequence length ×
hosts/prefill-hosts × user-popularity skew × arrival process} producing
per-cell latency distributions, per-cell SLO knees (geometric-expansion
search — no hard QPS cap), and ``BENCH_capacity.json`` + CSV curves
committed next to ``BENCH_relay.json``.

    PYTHONPATH=src python -m benchmarks.capacity [--quick]

See ``benchmarks/capacity/README.md`` for the matrix schema.
"""

from .knee import HARD_CAP_QPS, KneeResult, find_knee
from .matrix import (ALL_MODES, COST, HSTU, ISO_BURST_QPS, N_INST, SIM_S,
                     SLO_MS, MatrixSpec, cell_name, isolation_cell,
                     meets_slo, mode_config, run_cell, run_matrix,
                     run_point, run_tenant_point)
from .report import PROVENANCE_FIELDS, curves_csv, headline, render, write
from .workload import DEFAULT_POPULATION, WorkloadSpec, fixed_stream

__all__ = [
    "ALL_MODES", "COST", "DEFAULT_POPULATION", "HARD_CAP_QPS", "HSTU",
    "ISO_BURST_QPS", "KneeResult", "MatrixSpec", "N_INST",
    "PROVENANCE_FIELDS", "SIM_S", "SLO_MS", "WorkloadSpec", "cell_name",
    "curves_csv", "find_knee", "fixed_stream", "headline",
    "isolation_cell", "meets_slo", "mode_config", "render", "run_cell",
    "run_matrix", "run_point", "run_tenant_point", "write",
]
