"""Capacity harness: trace-realistic workload matrix, knee-finding, and
committed latency–throughput curves.

The measurement substrate the ROADMAP's open items prove themselves on:
a declarative matrix runner over {offered QPS × sequence length ×
hosts/prefill-hosts × user-popularity skew × arrival process} producing
per-cell latency distributions, per-cell SLO knees (geometric-expansion
search — no hard QPS cap), and ``BENCH_capacity.json`` + CSV curves
committed next to ``BENCH_relay.json``.

    PYTHONPATH=src python -m benchmarks.capacity [--quick]

See ``benchmarks/capacity/README.md`` for the matrix schema.
"""

from .knee import HARD_CAP_QPS, KneeResult, find_knee
from .matrix import (ALL_MODES, COST, HSTU, N_INST, SIM_S, SLO_MS,
                     MatrixSpec, cell_name, meets_slo, mode_config,
                     run_cell, run_matrix, run_point)
from .report import PROVENANCE_FIELDS, curves_csv, headline, render, write
from .workload import DEFAULT_POPULATION, WorkloadSpec, fixed_stream

__all__ = [
    "ALL_MODES", "COST", "DEFAULT_POPULATION", "HARD_CAP_QPS", "HSTU",
    "KneeResult", "MatrixSpec", "N_INST", "PROVENANCE_FIELDS", "SIM_S",
    "SLO_MS", "WorkloadSpec", "cell_name", "curves_csv", "find_knee",
    "fixed_stream", "headline", "meets_slo", "mode_config", "render",
    "run_cell", "run_matrix", "run_point", "write",
]
