"""Workload layer of the capacity harness: WHO arrives and WHEN.

``WorkloadSpec`` names one trace-realistic workload — a request
popularity law (uniform or Zipf over a multi-million-user population)
crossed with an arrival process (Poisson / diurnal sinusoid / MMPP
bursty) — and builds the timed ``(t, UserMeta)`` stream that feeds
``ClusterSim.run`` unchanged.  The samplers themselves live in
``repro.data.synthetic`` (the data substrate); this module is the
benchmark-facing declarative surface.

``fixed_stream`` is the legacy uniform-draw generator lifted out of
``benchmarks/figures.py`` (which re-exports it): users drawn uniformly
from a billion ids, optional rapid-refresh repeats.  It remains the
back-compat reference workload — the one whose degenerate 100% hit
rates motivated this package.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.core.types import UserMeta
from repro.data.synthetic import (ARRIVAL_PROCESSES, ZipfPopularity,
                                  capacity_stream)

#: default request-popularity population (ids): multi-million, per the
#: paper's serving-scale workload description
DEFAULT_POPULATION = 2_000_000


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One named workload cell: popularity skew × arrival process.

    ``skew=0`` + ``arrival="poisson"`` reproduces the legacy uniform
    stream's statistics (over a finite population); ``skew>0`` makes a
    head of hot users recur within cache lifetimes, which is what lets
    hit-rate and tail-latency curves respond to footprint pressure.
    """
    skew: float = 0.0
    arrival: str = "poisson"
    population: int = DEFAULT_POPULATION
    arrival_kw: Optional[Dict] = None

    def __post_init__(self):
        if self.arrival not in ARRIVAL_PROCESSES:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"known: {sorted(ARRIVAL_PROCESSES)}")

    @property
    def name(self) -> str:
        """Stable cell label, e.g. ``zipf1.1-mmpp`` / ``uniform-poisson``."""
        pop = "uniform" if self.skew == 0 else f"zipf{self.skew:g}"
        return f"{pop}-{self.arrival}"

    def head_share(self, top: int = 100) -> float:
        """Analytic share of requests landing on the ``top`` hottest
        users — the report's head-heaviness label."""
        return ZipfPopularity(self.population, self.skew).cdf(top)

    def tail_share(self, top: int = 100) -> float:
        """Analytic share of requests from BEYOND the ``top`` hottest
        users — the tail traffic only the sub-DRAM tiers can keep warm."""
        return ZipfPopularity(self.population, self.skew).tail_share(top)

    def stream(self, L: int, qps: float, duration_s: float, *,
               seed: int = 0, dim: int = 256, n_items: int = 512,
               incr_len: int = 64) -> Iterator[Tuple[float, UserMeta]]:
        return capacity_stream(
            L, qps, duration_s, skew=self.skew, population=self.population,
            arrival=self.arrival, seed=seed, dim=dim, n_items=n_items,
            incr_len=incr_len, arrival_kw=self.arrival_kw)

    def to_dict(self) -> Dict:
        d = {"skew": self.skew, "arrival": self.arrival,
             "population": self.population}
        if self.arrival_kw:
            d["arrival_kw"] = dict(self.arrival_kw)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "WorkloadSpec":
        return cls(skew=float(d.get("skew", 0.0)),
                   arrival=str(d.get("arrival", "poisson")),
                   population=int(d.get("population", DEFAULT_POPULATION)),
                   arrival_kw=d.get("arrival_kw"))


def fixed_stream(L, qps, dur, *, refresh=0.0, horizon=6000, seed=0,
                 dim=None, n_items=512) -> Iterable[Tuple[float, UserMeta]]:
    """Legacy benchmark stream (formerly ``figures._fixed_stream``):
    Poisson arrivals, users drawn uniformly from a billion ids, with
    probability ``refresh`` a repeat of one of the last ``horizon``
    users (the rapid-refresh knob that drives DRAM-tier reuse)."""
    rng = np.random.default_rng(seed)
    t, recent = 0.0, []
    while t < dur:
        t += rng.exponential(1.0 / qps)
        if recent and rng.random() < refresh:
            uid = int(rng.choice(recent[-horizon:]))
        else:
            uid = int(rng.integers(0, 10**9))
        recent.append(uid)
        yield t, UserMeta(user_id=uid, prefix_len=L, dim=dim or 256,
                          n_items=n_items)
