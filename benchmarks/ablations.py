"""Component ablations: each RelayGR mechanism removed in turn.

Shows each of the paper's three techniques is load-bearing:
  no-trigger   -> admit everything: special pool overloads (P99 blows);
  no-affinity  -> random special routing: producer/consumer miss, ranking
                  falls back to full inference (the paper's Fig.12 point);
  no-singleflight -> rapid same-user bursts trigger redundant reloads.

The first two now demonstrate the runtime's policy registry: the ablated
variant is just a different ``trigger_policy`` / ``router_policy`` string
in the ``ClusterConfig`` — no engine code changes.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import (ClusterConfig, GRCostModel, RelayGRService,
                        TriggerConfig, relay_config)
from repro.core.types import HitKind, UserMeta
from repro.models import get_config

COST = GRCostModel(get_config("hstu_gr"))


def _metas(n=400, L=4096, seed=0):
    rng = np.random.default_rng(seed)
    return [UserMeta(user_id=int(rng.integers(0, 10**9)), prefix_len=L)
            for _ in range(n)]


def ablation_affinity() -> List[Tuple]:
    """Affinity on vs off (``router_policy="random"``: the pre-infer
    producer and the ranking consumer land on independent random special
    instances, so they rendezvous only by chance)."""
    rows = []
    for policy in ("affinity", "random"):
        svc = RelayGRService(
            relay_config(trigger=TriggerConfig(n_instances=10, r2=0.5),
                         cluster=ClusterConfig(router_policy=policy,
                                               seed=1)),
            COST)
        hits = 0
        metas = _metas()
        for i, meta in enumerate(metas):
            sig = svc.on_retrieval(meta, now=i * 0.01)
            if sig is not None:
                svc.deliver_pre_infer(sig, now=i * 0.01)
            r = svc.on_rank(meta, now=i * 0.01 + 1e-3)
            hits += r.hit in (HitKind.HBM_HIT, HitKind.DRAM_HIT)
        rate = hits / len(metas)
        rows.append((f"ablation/{policy}-routing", rate * 1e6,
                     f"hit_rate={rate:.2f}"))
    return rows


def ablation_trigger() -> List[Tuple]:
    """Selective admission vs unconditional pre-inference (paper §2.4
    challenge 3: pre-inferring every request overloads the shared
    resources that ranking needs).  Realistic mixed-length traffic at
    high QPS: the ``sequence-aware`` trigger pre-infers only the ~10%
    at-risk requests; ``admit-all`` floods the special pool with
    pre-inference for *safe* short-sequence users.  Rank-stage routing
    uses the true risk test in both variants (``route_trigger``), so
    only the admission policy differs."""
    from repro.core.trigger import SequenceAwareTrigger
    from repro.data.synthetic import UserBehaviorStore, request_stream
    from repro.serving.simulator import ClusterSim
    rows = []
    store = UserBehaviorStore()
    for label, policy in (("selective-trigger", "sequence-aware"),
                          ("admit-all", "admit-all")):
        trig = TriggerConfig(n_instances=5, r2=0.4)
        sim = ClusterSim(
            relay_config(trigger=trig,
                         cluster=ClusterConfig(hbm_cache_bytes=4e9,
                                               trigger_policy=policy)),
            COST)
        sim.runtime.route_trigger = SequenceAwareTrigger(trig, COST)
        s = sim.run(request_stream(store, 900, 12.0))
        rows.append((f"ablation/{label}", s["p99_ms"] * 1e3,
                     f"p99={s['p99_ms']:.0f}ms succ={s['success_rate']:.3f} "
                     f"special_util={s['special_util']:.2f}"))
    return rows


def ablation_single_flight() -> List[Tuple]:
    """Pseudo-pre-infer dedup vs naive per-request reloads."""
    from repro.core import DRAMExpander, ExpanderConfig, HBMCacheStore
    from repro.core.cache import CacheEntry
    hbm = HBMCacheStore(10**12)
    exp = DRAMExpander(ExpanderConfig())
    exp.spill(CacheEntry(7, "psi", 10, 0.0, prefix_len=4096))
    burst = 8
    actions = [exp.pseudo_pre_infer(7, hbm, 0.0)[0] for _ in range(burst)]
    reloads = actions.count("reload")
    return [("ablation/single-flight", reloads,
             f"{reloads} reload for {burst}-req burst "
             f"(naive: {burst}; redundant_avoided="
             f"{exp.stats['redundant_avoided']})")]


ALL_ABLATIONS = [ablation_affinity, ablation_trigger,
                 ablation_single_flight]
