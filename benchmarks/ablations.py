"""Component ablations: each RelayGR mechanism removed in turn.

Shows each of the paper's three techniques is load-bearing:
  no-trigger   -> admit everything: special pool overloads (P99 blows);
  no-affinity  -> random special routing: producer/consumer miss, ranking
                  falls back to full inference (the paper's Fig.12 point);
  no-singleflight -> rapid same-user bursts trigger redundant reloads.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import GRCostModel, RelayGRService, ServiceConfig, TriggerConfig
from repro.core.types import HitKind, UserMeta
from repro.models import get_config

COST = GRCostModel(get_config("hstu_gr"))


def _metas(n=400, L=4096, seed=0):
    rng = np.random.default_rng(seed)
    return [UserMeta(user_id=int(rng.integers(0, 10**9)), prefix_len=L)
            for _ in range(n)]


def ablation_affinity() -> List[Tuple]:
    """Affinity on vs off (random special instance for ranking)."""
    rows = []
    for mode in ("affinity", "random"):
        svc = RelayGRService(ServiceConfig(
            trigger=TriggerConfig(n_instances=10, r2=0.5)), COST)
        rng = np.random.default_rng(1)
        hits = 0
        metas = _metas()
        for i, meta in enumerate(metas):
            sig = svc.on_retrieval(meta, now=i * 0.01)
            if sig is not None:
                if mode == "random":
                    sig.body["target"] = svc.special_names[
                        int(rng.integers(0, len(svc.special_names)))]
                svc.deliver_pre_infer(sig, now=i * 0.01)
            r = svc.on_rank(meta, now=i * 0.01 + 1e-3)
            hits += r.hit in (HitKind.HBM_HIT, HitKind.DRAM_HIT)
        rate = hits / len(metas)
        rows.append((f"ablation/{mode}-routing", rate * 1e6,
                     f"hit_rate={rate:.2f}"))
    return rows


def ablation_trigger() -> List[Tuple]:
    """Selective admission vs unconditional pre-inference (paper §2.4
    challenge 3: pre-inferring every request overloads the shared
    resources that ranking needs).  Realistic mixed-length traffic at
    high QPS: the trigger pre-infers only the ~10% at-risk requests;
    admit-all floods the special pool with pre-inference for *safe*
    short-sequence users."""
    from repro.data.synthetic import UserBehaviorStore, request_stream
    from repro.serving.simulator import ClusterSim, SimConfig
    rows = []
    store = UserBehaviorStore()
    for label, risk_all in (("selective-trigger", False),
                            ("admit-all", True)):
        trig = TriggerConfig(n_instances=5, r2=0.4,
                             rank_p99_budget_ms=0.1 if risk_all else 50.0,
                             q_m=1e5 if risk_all else 30.0)
        sim = ClusterSim(SimConfig(trigger=trig, hbm_cache_bytes=4e9), COST)
        if risk_all:
            # admit-all still *routes* ranking by the true risk test so
            # only the pre-inference policy differs
            real = TriggerConfig(n_instances=5, r2=0.4)
            from repro.core.trigger import SequenceAwareTrigger
            sim._route_trigger = SequenceAwareTrigger(real, COST)
            orig = sim._on_rank_arrival

            def routed(t, meta, rec, sim=sim):
                if sim._route_trigger.assess(meta).at_risk:
                    target = sim.router.ring.route(meta.user_id)
                else:
                    target = sim.normal[meta.user_id % len(sim.normal)]
                rec.t_rank_arrival = t
                sim.instances[target].enqueue(
                    {"kind": "rank", "meta": meta, "rec": rec}, t)

            sim._on_rank_arrival = routed
        s = sim.run(request_stream(store, 900, 12.0))
        rows.append((f"ablation/{label}", s["p99_ms"] * 1e3,
                     f"p99={s['p99_ms']:.0f}ms succ={s['success_rate']:.3f} "
                     f"special_util={s['special_util']:.2f}"))
    return rows


def ablation_single_flight() -> List[Tuple]:
    """Pseudo-pre-infer dedup vs naive per-request reloads."""
    from repro.core import DRAMExpander, ExpanderConfig, HBMCacheStore
    from repro.core.cache import CacheEntry
    hbm = HBMCacheStore(10**12)
    exp = DRAMExpander(ExpanderConfig())
    exp.spill(CacheEntry(7, "psi", 10, 0.0, prefix_len=4096))
    burst = 8
    actions = [exp.pseudo_pre_infer(7, hbm, 0.0)[0] for _ in range(burst)]
    reloads = actions.count("reload")
    return [("ablation/single-flight", reloads,
             f"{reloads} reload for {burst}-req burst "
             f"(naive: {burst}; redundant_avoided="
             f"{exp.stats['redundant_avoided']})")]


ALL_ABLATIONS = [ablation_affinity, ablation_trigger,
                 ablation_single_flight]
