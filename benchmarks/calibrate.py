"""Calibrate ``GRCostModel.batch_factor`` from measured group launches.

``PYTHONPATH=src python -m benchmarks.calibrate`` times
``BatchedLiveExecutor.rank_group`` on this host per (prefix-bucket,
batch-depth), derives the *marginal* cost of each non-dominant batch
member as a fraction of the dominant member's solo latency

    factor(bucket, n) = (group_ms / solo_ms - 1) / (n - 1)

and writes a table (default ``BENCH_batch_factors.json``) the cost
model loads via ``repro.core.costmodel.load_batch_calibration`` /
``GRCostModel.with_calibration`` — replacing the fixed 0.2 with the
measured per-shape numbers so the simulator's ``relay_batched`` /
``relay_multihost`` traces price batching the way THIS hardware does.
``--h2d`` additionally measures device-pool H2D — scatter-insert of k
fresh pages vs re-shipping the whole pool buffer (what every launch
pays without ``--device-pool``) — and emits the ``"h2d"`` block
``GRCostModel.scatter_ms`` prices from.

A TPU deployment re-runs this at its real model scale; the CPU smoke
numbers exist so the calibration path itself stays exercised in CI
(``--quick``).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np


def measure(buckets: Sequence[int], batches: Sequence[int],
            repeats: int = 3, incr_len: int = 16, n_items: int = 64
            ) -> Tuple[Dict, List[Tuple]]:
    """Measure rank_group wall times and derive the factor table.
    Returns (calibration table, CSV rows)."""
    import jax

    from repro.core import BatchingConfig, GRCostModel, UserMeta, \
        get_executor
    from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
    from repro.models import build_model, get_config
    from repro.serving.batching import PendingRank

    cfg = get_config("hstu_gr", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=n_items, incr_len=incr_len, max_len=2048))
    max_batch = max(batches)
    ex = get_executor("batched")(
        model, params, store, cost=GRCostModel(cfg),
        batching=BatchingConfig(max_batch=max_batch))

    def group_for(bucket: int, n: int) -> List[PendingRank]:
        group = []
        for i in range(n):
            meta = UserMeta(user_id=1000 * bucket + i, prefix_len=bucket,
                            incr_len=incr_len, n_items=n_items)
            psi, _, _ = ex.pre_infer(meta)
            group.append(PendingRank(user_id=meta.user_id, psi=psi,
                                     prefix_len=bucket, meta=meta))
        return group

    def timed(group) -> float:
        ex.rank_group(group)                      # compile/warm
        return float(np.median([ex.rank_group(group)[1]
                                for _ in range(repeats)]))

    rows, table = [], {}
    for bucket in buckets:
        solo_ms = timed(group_for(bucket, 1))
        per_bucket = {}
        for n in batches:
            if n <= 1:
                continue
            group_ms = timed(group_for(bucket, n))
            factor = max(0.0, (group_ms / solo_ms - 1.0) / (n - 1))
            per_bucket[str(n)] = round(factor, 4)
            rows.append((f"calibrate/bucket{bucket}/batch{n}",
                         group_ms * 1e3,
                         f"solo={solo_ms:.2f}ms group={group_ms:.2f}ms "
                         f"factor={factor:.3f}"))
        table[str(bucket)] = per_bucket
    factors = [v for row in table.values() for v in row.values()]
    cal = {"default": round(float(np.mean(factors)), 4) if factors else 0.2,
           "meta": {"model": "hstu_gr-smoke", "repeats": repeats,
                    "incr_len": incr_len, "n_items": n_items},
           "buckets": table}
    return cal, rows


def measure_h2d(pool_pages: Sequence[int], insert_pages: Sequence[int],
                repeats: int = 3, page_tokens: int = 64
                ) -> Tuple[Dict, List[Tuple]]:
    """Measure device-pool H2D: scatter-insert (only the fresh pages
    cross the link, donated in-place update) vs full-pool re-ship (what
    every ``rank_with_pages`` launch pays WITHOUT the device-resident
    pool) per (pool pages, inserted pages) geometry.

    Emits the ``"h2d"`` calibration block ``GRCostModel.scatter_ms``
    reads via ``with_calibration``: ``scatter_bw`` / ``reship_bw`` are
    the median measured link bandwidths (bytes/s), ``grid`` keeps the
    per-geometry wall times for inspection."""
    import jax

    from repro.core.paging import DevicePagePool, PageLayout
    from repro.models import get_config

    cfg = get_config("hstu_gr", smoke=True)
    layout = PageLayout.from_model_config(cfg, page_tokens)
    page_bytes = layout.page_bytes
    dtype = np.float32 if cfg.dtype == "float32" else np.float16

    rows, grid = [], {}
    scatter_bws, reship_bws = [], []
    rng = np.random.default_rng(0)
    for npages in pool_pages:
        buf = rng.standard_normal(
            (npages + 1, page_tokens, cfg.n_heads,
             cfg.head_dim)).astype(dtype)
        buf[npages] = 0.0                       # null page
        per_pool = {}
        for k in insert_pages:
            if k > npages:
                continue
            pages = list(range(k))
            pool = DevicePagePool(npages, page_bytes)
            pool.scatter(pages, buf)            # compile/warm + buffer init
            pool.device_buffer.block_until_ready()

            def t_scatter():
                t0 = time.perf_counter()
                pool.scatter(pages, buf)
                pool.device_buffer.block_until_ready()
                return (time.perf_counter() - t0) * 1e3

            def t_reship():
                t0 = time.perf_counter()
                jax.device_put(buf).block_until_ready()
                return (time.perf_counter() - t0) * 1e3

            t_reship()                          # warm the transfer path
            s_ms = float(np.median([t_scatter() for _ in range(repeats)]))
            r_ms = float(np.median([t_reship() for _ in range(repeats)]))
            scatter_bws.append(k * page_bytes / (s_ms / 1e3))
            reship_bws.append(buf.nbytes / (r_ms / 1e3))
            per_pool[str(k)] = {"scatter_ms": round(s_ms, 4),
                                "reship_ms": round(r_ms, 4)}
            rows.append((f"h2d/pool{npages}/insert{k}", s_ms * 1e3,
                         f"scatter={s_ms:.3f}ms reship={r_ms:.3f}ms "
                         f"x{r_ms / max(s_ms, 1e-9):.0f}"))
        grid[str(npages)] = per_pool
    h2d = {"scatter_bw": float(np.median(scatter_bws)) if scatter_bws
           else 0.0,
           "reship_bw": float(np.median(reship_bws)) if reship_bws
           else 0.0,
           "page_tokens": page_tokens, "page_bytes": page_bytes,
           "grid": grid}
    return h2d, rows


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(
        description="measure rank_group wall times per (bucket, batch) "
                    "and emit a batch-factor table for GRCostModel")
    ap.add_argument("--out", default="BENCH_batch_factors.json")
    ap.add_argument("--buckets", default="64,128,256",
                    help="comma-separated prefix buckets to measure")
    ap.add_argument("--batches", default="1,2,4,8")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--h2d", action="store_true",
                    help="also measure device-pool H2D: scatter-insert "
                         "vs full-pool re-ship per (pool pages, "
                         "inserted pages); adds the 'h2d' block "
                         "GRCostModel.scatter_ms prices from")
    ap.add_argument("--pool-pages", default="256,1024",
                    help="pool geometries for --h2d")
    ap.add_argument("--insert-pages", default="1,8,64",
                    help="scatter sizes for --h2d")
    ap.add_argument("--quick", action="store_true",
                    help="one bucket, depths (1,2), single repeat "
                         "(CI smoke: exercises the path, not the numbers)")
    args = ap.parse_args(argv)
    buckets = [int(b) for b in args.buckets.split(",")]
    batches = [int(b) for b in args.batches.split(",")]
    pool_pages = [int(b) for b in args.pool_pages.split(",")]
    insert_pages = [int(b) for b in args.insert_pages.split(",")]
    if args.quick:
        buckets, batches, args.repeats = buckets[:1], [1, 2], 1
        pool_pages, insert_pages = pool_pages[:1], insert_pages[:2]

    cal, rows = measure(buckets, batches, repeats=args.repeats)
    if args.h2d:
        h2d, h2d_rows = measure_h2d(pool_pages, insert_pages,
                                    repeats=args.repeats)
        cal["h2d"] = h2d
        rows += h2d_rows
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    with open(args.out, "w") as f:
        json.dump(cal, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out} (default factor {cal['default']}, "
          f"fixed model default 0.2)")
    return cal


if __name__ == "__main__":
    main()
