"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV — one block per paper
table/figure (benchmarks/figures.py), the live-compute microbenchmarks
(benchmarks/microbench.py) and, when dry-run artifacts exist, the
roofline summary (benchmarks/roofline.py).

Full runs also write ``BENCH_relay.json`` (override with
``--relay-json``): the machine-readable per-mode perf headline — P99,
SLO-compliant throughput, hit rates — so successive PRs have a
serving-perf trajectory to diff.  ``--quick`` skips the write unless a
path is given, so reduced runs never clobber the committed trajectory.

``--quick`` runs a reduced subset (used by CI / test_benchmarks).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

# every BENCH_relay.json must report these serving modes
RELAY_MODES = ("baseline", "relay", "relay_dram", "relay_batched",
               "relay_paged", "relay_devpool", "relay_segments",
               "relay_multihost", "relay_disagg", "relay_cold",
               "relay_tenants")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="",
                    help="substring filter on benchmark function names")
    ap.add_argument("--relay-json", default=None,
                    help="perf-headline output path ('' disables; default "
                         "BENCH_relay.json, or skipped under --quick so a "
                         "reduced run never overwrites the committed "
                         "full-run trajectory)")
    args = ap.parse_args(argv)
    if args.relay_json is None:
        args.relay_json = "" if args.quick else "BENCH_relay.json"

    from benchmarks import ablations, figures, microbench

    fig_fns = list(figures.ALL_FIGURES) + list(ablations.ALL_ABLATIONS)
    micro_fns = list(microbench.ALL_MICRO)
    if args.quick:
        fig_fns = [figures.fig11d_slo_throughput,
                   figures.fig12_local_vs_remote,
                   figures.table1_kv_footprint]
        micro_fns = []
    if args.only:
        fig_fns = [f for f in fig_fns if args.only in f.__name__]
        micro_fns = [f for f in micro_fns if args.only in f.__name__]

    print("name,us_per_call,derived")
    for fn in fig_fns + micro_fns:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # report, keep going
            print(f"{fn.__name__},0,ERROR: {type(e).__name__}: {e}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"# {fn.__name__} took {time.time() - t0:.1f}s",
              file=sys.stderr)

    if args.relay_json and not args.only:
        t0 = time.time()
        headline = figures.bench_relay_summary(quick=args.quick)
        missing = [f"{mode}.{field}"
                   for mode in RELAY_MODES
                   for field in ("slo_qps", "p99_ms")
                   if field not in headline.get(mode, {})]
        if missing:  # CI gates on the headline schema — fail loudly
            raise SystemExit(f"BENCH_relay headline incomplete: {missing}")
        with open(args.relay_json, "w") as f:
            json.dump(headline, f, indent=1, sort_keys=True)
        print(f"# wrote {args.relay_json} in {time.time() - t0:.1f}s",
              file=sys.stderr)

    # roofline summary (if the dry-run has produced artifacts)
    try:
        from benchmarks import roofline
        rows = roofline.load()
        for r in rows:
            print(f"roofline/{r['arch']}/{r['shape']},"
                  f"{r['roofline_bound_s'] * 1e6:.1f},"
                  f"dominant={r['dominant']} useful={r['useful_ratio']}")
    except Exception as e:
        print(f"roofline,0,unavailable: {e}")


if __name__ == "__main__":
    main()
