"""Serving-perf regression gate: candidate run vs the committed headline.

``python -m benchmarks.check_regression --candidate /tmp/bench.json``
compares a fresh ``benchmarks.run`` headline against the committed
``BENCH_relay.json`` per mode and FAILS (exit 1) with a readable
per-mode diff when any metric regresses past its stated tolerance:

  * latency  — ``p99_ms`` / ``rank_p99_ms`` may rise at most
    ``--latency-tol`` (default 5%): the fixed-point run (L=2048,
    60 QPS) is a seeded virtual-clock sim at full duration even under
    ``--quick``, so this bound is tight;
  * hit rates — ``hbm_hit`` / ``dram_hit`` / ``miss`` must stay within
    ``--hit-tol`` (default 0.02) absolute of the committed values;
  * throughput — ``slo_qps`` must reach ``--qps-floor`` of the
    committed value.  The full-precision bisection warrants the default
    0.85; ``--quick`` lowers it to 0.55 because the CI smoke bisects
    coarsely (~30% tolerance) over 4 s sims;
  * cross-mode — ``relay_paged`` must keep ``relay_batched``'s HBM hit
    rate (same trigger, same byte budget: paging may not cost
    admissions) and the COMMITTED file must hold their ``slo_qps``
    within 5% of each other, the paged-window acceptance bound;
  * cold tier — ``relay_cold`` must strictly beat ``relay_segments``
    on the tail-probe reuse fraction (hbm + dram + cold at 1.15x the
    segments knee) and hold >= 95% of its committed ``slo_qps``; on
    the committed capacity matrix every skewed POISSON cell's
    ``relay_cold`` knee must be >= the ``relay_batched`` knee (the
    Zipf-tail lift; MMPP knees carry burst-phase noise larger than
    the lift and are gated by the knee floor only);
  * multi-tenant — ``relay_tenants`` must keep ``relay_batched``'s
    hit rates within 2% absolute (the equal-share partition of a
    symmetric trace is near-free) and its committed ``slo_qps``
    within 10%; the capacity headline's ``isolation`` record must
    show tenant B's MMPP burst moving neither tenant A's hit rate
    (``--hit-tol``) nor A's SLO knee (``--iso-knee-tol``, 10%).

Replaces the old sanity-only ``slo_qps >= 0.8 * relay`` check: every
mode is now gated against its own committed trajectory, so a perf
regression in any deployment flavour fails CI instead of rotting
silently in an artifact.

Capacity gating (``--capacity-candidate``): a fresh
``python -m benchmarks.capacity`` headline is diffed against the
committed ``BENCH_capacity.json`` over the intersection of matrix
cells — per-cell knee QPS must reach ``--qps-floor`` of the committed
knee, and every POISSON cell's goodput must rise monotonically up to
its knee (a goodput dip below the knee means admission is collapsing
before saturation — a scheduler bug, not a tolerance matter; under
MMPP the dip inference doesn't hold, see ``compare_capacity``).

Both gates refuse (exit 2, distinct from a regression's exit 1) to
diff headlines produced under different workloads: the meta blocks
must agree on provenance (seed/horizon/arrival/workload for the relay
headline; seed/population/slo_ms for capacity), a ``--quick``
capacity file is never accepted as the committed reference, and a
capacity candidate whose meta lacks the ``quick`` flag entirely is
refused as schema drift (the gate cannot pick tolerances for a file
that won't say whether it is a smoke run).
"""

from __future__ import annotations

import argparse
import json
import sys

GATED_LATENCY = ("p99_ms", "rank_p99_ms")
GATED_HITS = ("hbm_hit", "dram_hit", "cold_hit", "miss")

#: BENCH_relay.json meta fields that pin the workload a headline was
#: measured under; two headlines disagreeing on any of these are
#: different experiments, and diffing them is refused outright
RELAY_PROVENANCE = ("L", "offered_qps", "slo_ms", "seed", "horizon",
                    "arrival", "workload")


class ProvenanceMismatch(Exception):
    """Raised when two headlines were measured under different
    workloads — the diff would compare apples to oranges."""


def check_provenance(reference: dict, candidate: dict,
                     fields=RELAY_PROVENANCE, *, label: str = "") -> None:
    """Refuse to diff headlines with mismatched workload provenance.

    Only fields the *reference* meta actually carries are enforced, so
    the gate stays usable against pre-provenance committed files; a
    field the reference has but the candidate lacks IS a mismatch.
    """
    ref_meta = reference.get("meta", {})
    cand_meta = candidate.get("meta", {})
    bad = [f for f in fields if f in ref_meta
           and cand_meta.get(f) != ref_meta[f]]
    if bad:
        detail = ", ".join(
            f"{f}: committed={ref_meta[f]!r} candidate="
            f"{cand_meta.get(f, '<absent>')!r}" for f in bad)
        raise ProvenanceMismatch(
            f"{label}workload provenance mismatch — refusing to diff "
            f"({detail}); regenerate the candidate under the committed "
            f"workload or recommit the reference")


def _fmt(v) -> str:
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def compare(reference: dict, candidate: dict, *, latency_tol: float,
            hit_tol: float, qps_floor: float) -> list:
    """Return [(mode, field, ref, cand, limit_desc, ok), ...]."""
    rows = []
    for mode in sorted(k for k in reference if k != "meta"):
        ref, cand = reference[mode], candidate.get(mode)
        if cand is None:
            rows.append((mode, "<mode>", "present", "MISSING", "required",
                         False))
            continue
        for f in GATED_LATENCY:
            lim = ref[f] * (1 + latency_tol)
            rows.append((mode, f, ref[f], cand.get(f),
                         f"<= {lim:.3f} (+{latency_tol:.0%})",
                         cand.get(f) is not None and cand[f] <= lim))
        for f in GATED_HITS:
            if f not in ref:
                continue   # pre-cold-tier committed file: nothing to gate
            rows.append((mode, f, ref[f], cand.get(f),
                         f"± {hit_tol}",
                         cand.get(f) is not None
                         and abs(cand[f] - ref[f]) <= hit_tol))
        lim = ref["slo_qps"] * qps_floor
        rows.append((mode, "slo_qps", ref["slo_qps"], cand.get("slo_qps"),
                     f">= {lim:.1f} ({qps_floor:.0%} of committed)",
                     cand.get("slo_qps") is not None
                     and cand["slo_qps"] >= lim))

    # paged-window acceptance: relay_paged rides relay_batched's cache
    if "relay_paged" in reference and "relay_batched" in reference:
        rb, rp = candidate.get("relay_batched"), candidate.get("relay_paged")
        if rb and rp:
            rows.append(("relay_paged", "hbm_hit == relay_batched",
                         rb["hbm_hit"], rp["hbm_hit"], "± 0.005",
                         abs(rp["hbm_hit"] - rb["hbm_hit"]) <= 0.005))
        rb, rp = reference["relay_batched"], reference["relay_paged"]
        rows.append(("relay_paged", "slo_qps vs relay_batched (committed)",
                     rb["slo_qps"], rp["slo_qps"], "within 5%",
                     abs(rp["slo_qps"] - rb["slo_qps"])
                     <= 0.05 * rb["slo_qps"]))

    # device-pool acceptance: relay_devpool is relay_paged with the
    # device-resident data plane — a pure launch-path property that is
    # byte-free in the simulator, so its sim trace must ride
    # relay_paged's (hit rates tight, committed slo within 5%); the
    # live h2d win itself is gated by the CI smoke's
    # ``launch_reships == 0`` assert, not this table
    if "relay_devpool" in reference and "relay_paged" in reference:
        rp = candidate.get("relay_paged")
        rd = candidate.get("relay_devpool")
        if rp and rd:
            rows.append(("relay_devpool", "hbm_hit == relay_paged",
                         rp["hbm_hit"], rd["hbm_hit"], "± 0.005",
                         abs(rd["hbm_hit"] - rp["hbm_hit"]) <= 0.005))
        rp = reference["relay_paged"]
        rd = reference["relay_devpool"]
        rows.append(("relay_devpool", "slo_qps vs relay_paged (committed)",
                     rp["slo_qps"], rd["slo_qps"], "within 5%",
                     abs(rd["slo_qps"] - rp["slo_qps"])
                     <= 0.05 * rp["slo_qps"]))

    # beyond-prefix acceptance: relay_segments is relay_paged with
    # candidate-independent interior segments cached alongside the
    # prefix — the point of the mode is MORE reused tokens per hit, so
    # its reused-token fraction must strictly exceed relay_paged's
    # (candidate and committed), and the committed slo_qps may not fall
    # below relay_paged (segment reuse shortens critical-path ranking;
    # one-sided: faster is success)
    if "relay_segments" in reference and "relay_paged" in reference:
        rp = candidate.get("relay_paged")
        rs = candidate.get("relay_segments")
        if rp and rs and "reused_frac" in rp and "reused_frac" in rs:
            rows.append(("relay_segments", "reused_frac > relay_paged",
                         rp["reused_frac"], rs["reused_frac"],
                         "strictly greater",
                         rs["reused_frac"] > rp["reused_frac"]))
        rp = reference["relay_paged"]
        rs = reference["relay_segments"]
        if "reused_frac" in rp and "reused_frac" in rs:
            rows.append(("relay_segments",
                         "reused_frac > relay_paged (committed)",
                         rp["reused_frac"], rs["reused_frac"],
                         "strictly greater",
                         rs["reused_frac"] > rp["reused_frac"]))
        rows.append(("relay_segments",
                     "slo_qps vs relay_paged (committed)",
                     rp["slo_qps"], rs["slo_qps"],
                     ">= relay_paged",
                     rs["slo_qps"] >= rp["slo_qps"]))

    # multi-host acceptance: striping the pools over two hosts moves
    # WHERE producer and consumer rendezvous, never whether they do —
    # affinity hit rates must stay within 2% absolute of single-host
    # (the ISSUE/ROADMAP acceptance bound), and the committed slo_qps
    # within 10% (the owner-map hop is free in the model; the spread
    # covers per-host load-skew effects on the bisected headline)
    if "relay_multihost" in reference and "relay_batched" in reference:
        rb = candidate.get("relay_batched")
        rm = candidate.get("relay_multihost")
        if rb and rm:
            for f in ("hbm_hit", "dram_hit", "miss"):
                rows.append(("relay_multihost", f"{f} == relay_batched",
                             rb[f], rm[f], "± 0.02",
                             abs(rm[f] - rb[f]) <= 0.02))
        rb = reference["relay_batched"]
        rm = reference["relay_multihost"]
        rows.append(("relay_multihost",
                     "slo_qps vs relay_batched (committed)",
                     rb["slo_qps"], rm["slo_qps"], "within 10%",
                     abs(rm["slo_qps"] - rb["slo_qps"])
                     <= 0.10 * rb["slo_qps"]))

    # disaggregated-prefill acceptance: carving the side path onto a
    # dedicated host must not cost rendezvous — hit rates within 2%
    # absolute of relay_multihost (the shipment lands inside the
    # retrieval slack at the reference point) — and the committed
    # slo_qps may not fall more than 10% below relay_multihost (the
    # freed ranking slots should pay for the NIC hop, not the reverse;
    # one-sided: being FASTER is success, not drift)
    if "relay_disagg" in reference and "relay_multihost" in reference:
        rm = candidate.get("relay_multihost")
        rd = candidate.get("relay_disagg")
        if rm and rd:
            for f in ("hbm_hit", "dram_hit", "miss"):
                rows.append(("relay_disagg", f"{f} == relay_multihost",
                             rm[f], rd[f], "± 0.02",
                             abs(rd[f] - rm[f]) <= 0.02))
        rm = reference["relay_multihost"]
        rd = reference["relay_disagg"]
        rows.append(("relay_disagg",
                     "slo_qps vs relay_multihost (committed)",
                     rm["slo_qps"], rd["slo_qps"],
                     ">= 90% of relay_multihost",
                     rd["slo_qps"] >= 0.90 * rm["slo_qps"]))

    # cold-tier acceptance: relay_cold is relay_segments with a bounded
    # DRAM tier and a host-local cold store under it.  The tier's point
    # is the TAIL: past the admission knee, rate-limited returning
    # users must be served out of the hierarchy, so relay_cold's
    # tail-probe reuse fraction (hbm + dram + cold at 1.15x
    # relay_segments' slo_qps) must strictly exceed relay_segments'
    # (candidate and committed), and the committed slo_qps may not fall
    # below 95% of relay_segments (the disk path must not tax the
    # knee)
    if "relay_cold" in reference and "relay_segments" in reference:
        rs = candidate.get("relay_segments")
        rc = candidate.get("relay_cold")
        if rs and rc and "tail_reuse_frac" in rs \
                and "tail_reuse_frac" in rc:
            rows.append(("relay_cold",
                         "tail_reuse_frac > relay_segments",
                         rs["tail_reuse_frac"], rc["tail_reuse_frac"],
                         "strictly greater",
                         rc["tail_reuse_frac"] > rs["tail_reuse_frac"]))
        rs = reference["relay_segments"]
        rc = reference["relay_cold"]
        if "tail_reuse_frac" in rs and "tail_reuse_frac" in rc:
            rows.append(("relay_cold",
                         "tail_reuse_frac > relay_segments (committed)",
                         rs["tail_reuse_frac"], rc["tail_reuse_frac"],
                         "strictly greater",
                         rc["tail_reuse_frac"] > rs["tail_reuse_frac"]))
        rows.append(("relay_cold",
                     "slo_qps vs relay_segments (committed)",
                     rs["slo_qps"], rc["slo_qps"],
                     ">= 95% of relay_segments",
                     rc["slo_qps"] >= 0.95 * rs["slo_qps"]))

    # multi-tenant acceptance: relay_tenants is relay_batched with the
    # fleet split into two equal-share tenants (per-tenant byte quotas
    # on every tier + per-tenant admission buckets) over the IDENTICAL
    # arrival trace (tenant = user_id % 2, no RNG draw).  Partitioning
    # symmetric traffic must be near-free: hit rates within 2% absolute
    # of relay_batched and the committed slo_qps within 10% (each
    # tenant's bucket is half the pool rate — never binding below the
    # untenanted ceiling for a symmetric split).  The isolation
    # property itself (one tenant bursting must not move the other) is
    # gated on the capacity headline's ``isolation`` record.
    if "relay_tenants" in reference and "relay_batched" in reference:
        rb = candidate.get("relay_batched")
        rt = candidate.get("relay_tenants")
        if rb and rt:
            for f in ("hbm_hit", "dram_hit", "miss"):
                rows.append(("relay_tenants", f"{f} == relay_batched",
                             rb[f], rt[f], "± 0.02",
                             abs(rt[f] - rb[f]) <= 0.02))
        rb = reference["relay_batched"]
        rt = reference["relay_tenants"]
        rows.append(("relay_tenants",
                     "slo_qps vs relay_batched (committed)",
                     rb["slo_qps"], rt["slo_qps"], "within 10%",
                     abs(rt["slo_qps"] - rb["slo_qps"])
                     <= 0.10 * rb["slo_qps"]))
    return rows


def _curve_below_knee(cell: dict) -> list:
    knee = cell.get("knee_qps", 0.0)
    return [r for r in cell.get("curve", ())
            if r.get("offered_qps", 0.0) <= knee + 1e-9]


def _goodput_monotone(cell: dict, tol: float) -> bool:
    """Goodput must rise with offered load up to the knee: each point
    may dip at most ``tol`` (relative) below the running maximum."""
    best = 0.0
    for row in _curve_below_knee(cell):
        g = row.get("goodput_qps", 0.0)
        if g < best * (1 - tol):
            return False
        best = max(best, g)
    return True


def compare_isolation(reference: dict, candidate: dict, *,
                      hit_tol: float, knee_tol: float) -> list:
    """Gate the two-tenant burst-isolation record (the ``isolation``
    block of ``BENCH_capacity.json``): tenant B's MMPP burst must move
    neither tenant A's hit rate (within ``hit_tol`` absolute) nor A's
    SLO knee (within ``knee_tol`` relative).  Both the committed record
    and — when present — the candidate's fresh record are gated, so a
    partition regression fails CI from either side."""
    rows = []
    for label, head in (("committed", reference),
                        ("candidate", candidate)):
        iso = (head or {}).get("isolation")
        if not iso:
            continue
        solo, burst = iso.get("solo", {}), iso.get("burst", {})
        name = f"isolation[{label}]"
        hs, hb = solo.get("hit_rate"), burst.get("hit_rate")
        rows.append((name, "tenant A hit_rate under B burst",
                     hs, hb, f"± {hit_tol}",
                     hs is not None and hb is not None
                     and abs(hb - hs) <= hit_tol))
        ks, kb = solo.get("knee_qps"), burst.get("knee_qps")
        rows.append((name, "tenant A knee_qps under B burst",
                     ks, kb, f"within {knee_tol:.0%}",
                     ks is not None and kb is not None and ks > 0
                     and abs(kb - ks) <= knee_tol * ks))
    if not rows:
        rows.append(("isolation", "<record>", "present", "MISSING",
                     "committed isolation record required", False))
    return rows


def compare_capacity(reference: dict, candidate: dict, *,
                     knee_floor: float, curve_tol: float) -> list:
    """Gate a fresh capacity headline against the committed one over
    the intersection of matrix cells (the CI smoke runs a subset of
    the committed full matrix, keyed by the same cell names)."""
    ref_cells = reference.get("cells", {})
    cand_cells = candidate.get("cells", {})
    shared = sorted(set(ref_cells) & set(cand_cells))
    rows = []
    if not shared:
        rows.append(("capacity", "<cells>", len(ref_cells), 0,
                     "cell-key intersection non-empty", False))
        return rows
    for name in shared:
        ref, cand = ref_cells[name], cand_cells[name]
        lim = ref["knee_qps"] * knee_floor
        rows.append((name, "knee_qps", ref["knee_qps"],
                     cand.get("knee_qps"),
                     f">= {lim:.1f} ({knee_floor:.0%} of committed)",
                     cand.get("knee_qps") is not None
                     and cand["knee_qps"] >= lim))
        # goodput monotonicity is a Poisson-only inference: under MMPP
        # the burst phase realigns with every offered-rate rescale (the
        # stream is re-seeded per probe), so goodput below the knee
        # legitimately swings tens of percent between adjacent probes —
        # a dip there is burst alignment, not admission collapse.
        # Bursty cells stay gated by the knee floor above.
        if ref.get("workload", {}).get("arrival", "poisson") != "poisson":
            continue
        rows.append((name, "goodput monotone to knee",
                     "monotone", "monotone" if
                     _goodput_monotone(cand, curve_tol) else "DIP",
                     f"no >{curve_tol:.0%} dip below running max",
                     _goodput_monotone(cand, curve_tol)))

    # cold-tier acceptance (committed matrix): on every skewed
    # (Zipf-tail) POISSON cell the full hierarchy must LIFT the knee
    # over the DRAM-less batched deployment — returning tail users
    # revived off the cold store instead of re-prefilled is the whole
    # point of the tier.  MMPP cells are excluded for the same reason
    # as the monotonicity gate: their knees carry burst-phase noise
    # larger than the lift itself on 12 s sims (they remain gated by
    # the per-cell knee floor).
    for name, ref in sorted(ref_cells.items()):
        if not name.startswith("relay_cold/"):
            continue
        wl = ref.get("workload", {})
        if wl.get("skew", 0.0) <= 0.0:
            continue
        if wl.get("arrival", "poisson") != "poisson":
            continue
        peer = "relay_batched/" + name.split("/", 1)[1]
        pr = ref_cells.get(peer)
        if pr is None:
            continue
        rows.append((name, f"knee_qps >= {peer} (committed)",
                     pr["knee_qps"], ref["knee_qps"],
                     "cold tier lifts the Zipf-tail knee",
                     ref["knee_qps"] >= pr["knee_qps"]))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when the serving perf headline regresses "
                    "past tolerance vs the committed BENCH_relay.json")
    ap.add_argument("--candidate", default=None,
                    help="headline json from the fresh benchmarks.run")
    ap.add_argument("--reference", default="BENCH_relay.json",
                    help="committed trajectory to gate against")
    ap.add_argument("--capacity-candidate", default=None,
                    help="headline json from a fresh "
                         "benchmarks.capacity run")
    ap.add_argument("--capacity-reference", default="BENCH_capacity.json",
                    help="committed capacity matrix to gate against")
    ap.add_argument("--latency-tol", type=float, default=0.05)
    ap.add_argument("--hit-tol", type=float, default=0.02)
    ap.add_argument("--curve-tol", type=float, default=None,
                    help="max relative goodput dip below the knee "
                         "(default 0.02, or 0.10 with --quick)")
    ap.add_argument("--qps-floor", type=float, default=None,
                    help="min fraction of committed slo_qps / knee_qps "
                         "(default 0.85, or 0.55 with --quick)")
    ap.add_argument("--iso-knee-tol", type=float, default=None,
                    help="max relative shift of tenant A's knee under "
                         "tenant B's burst (default 0.10, or 0.35 with "
                         "--quick: the coarse bisection alone carries "
                         "~30% bracket slack)")
    ap.add_argument("--quick", action="store_true",
                    help="candidate came from a --quick run: coarse "
                         "4 s-sim bisection, so widen the slo_qps floor")
    args = ap.parse_args(argv)
    if args.qps_floor is None:
        args.qps_floor = 0.55 if args.quick else 0.85
    if args.curve_tol is None:
        args.curve_tol = 0.10 if args.quick else 0.02
    if args.iso_knee_tol is None:
        args.iso_knee_tol = 0.35 if args.quick else 0.10
    if not args.candidate and not args.capacity_candidate:
        ap.error("need --candidate and/or --capacity-candidate")

    rows = []
    try:
        if args.candidate:
            with open(args.reference) as f:
                reference = json.load(f)
            with open(args.candidate) as f:
                candidate = json.load(f)
            check_provenance(reference, candidate, RELAY_PROVENANCE,
                             label="relay: ")
            rows += compare(reference, candidate,
                            latency_tol=args.latency_tol,
                            hit_tol=args.hit_tol,
                            qps_floor=args.qps_floor)
        if args.capacity_candidate:
            from benchmarks.capacity import PROVENANCE_FIELDS
            with open(args.capacity_reference) as f:
                cap_ref = json.load(f)
            with open(args.capacity_candidate) as f:
                cap_cand = json.load(f)
            if cap_ref.get("meta", {}).get("quick"):
                raise ProvenanceMismatch(
                    "capacity: committed reference "
                    f"{args.capacity_reference} is a --quick run — "
                    "refusing to gate against a smoke matrix; commit a "
                    "full run")
            # the candidate must SAY whether it is a smoke run: a
            # headline whose meta lacks the ``quick`` flag is schema
            # drift (or a hand-rolled file) and the knee tolerances
            # below would be meaningless against it
            if "quick" not in cap_cand.get("meta", {}):
                raise ProvenanceMismatch(
                    f"capacity: candidate {args.capacity_candidate} "
                    "has no meta.quick flag — cannot tell a smoke "
                    "matrix from a full run; regenerate the candidate "
                    "with python -m benchmarks.capacity")
            check_provenance(cap_ref, cap_cand, PROVENANCE_FIELDS,
                             label="capacity: ")
            rows += compare_capacity(cap_ref, cap_cand,
                                     knee_floor=args.qps_floor,
                                     curve_tol=args.curve_tol)
            rows += compare_isolation(cap_ref, cap_cand,
                                      hit_tol=args.hit_tol,
                                      knee_tol=args.iso_knee_tol)
    except ProvenanceMismatch as exc:
        print(f"REFUSED: {exc}", file=sys.stderr)
        return 2

    width = max(len(r[0]) + len(r[1]) for r in rows) + 3
    print(f"perf regression gate: candidate="
          f"{args.candidate or args.capacity_candidate} "
          f"vs committed="
          f"{args.reference if args.candidate else args.capacity_reference}"
          f"{' [quick tolerances]' if args.quick else ''}")
    failures = []
    for mode, field, ref, cand, limit, ok in rows:
        tag = "ok  " if ok else "FAIL"
        print(f"  {tag} {(mode + '.' + field).ljust(width)} "
              f"committed={_fmt(ref).ljust(9)} got={_fmt(cand).ljust(9)} "
              f"limit: {limit}")
        if not ok:
            failures.append(f"{mode}.{field}")
    if failures:
        print(f"REGRESSION: {len(failures)} metric(s) out of tolerance: "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    print(f"all {len(rows)} gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
