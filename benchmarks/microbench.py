"""Real-compute microbenchmarks: jitted HSTU prefill / rank-with-cache /
fallback steps on this host (CPU), plus kernel interpret-mode checks.

These are the live-engine operation costs (us_per_call measured, not
simulated) — the numbers a TPU deployment would re-measure to
recalibrate the cost model (EXPERIMENTS.md §Calibration)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import LiveExecutor
from repro.core.types import UserMeta
from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
from repro.models import get_model


def _time(fn, n=5) -> float:
    fn()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def live_engine_ops() -> List[Tuple]:
    model = get_model("hstu_gr", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(n_items=64, incr_len=16))
    ex = LiveExecutor(model, params, store)
    meta = UserMeta(user_id=7, prefix_len=256, incr_len=16, n_items=64)
    rows = []
    psi, nbytes, _ = ex.pre_infer(meta)
    rows.append(("micro/pre_infer_256tok",
                 _time(lambda: ex.pre_infer(meta)),
                 f"psi={nbytes / 1e6:.2f}MB"))
    rows.append(("micro/rank_cached",
                 _time(lambda: ex.rank_cached(meta, psi)),
                 "scores (1,64,1)"))
    rows.append(("micro/rank_full_fallback",
                 _time(lambda: ex.rank_full(meta)),
                 "baseline path"))
    return rows


def kernel_interpret() -> List[Tuple]:
    from repro.kernels import ops
    rows = []
    B, S, H, D = 1, 512, 4, 64
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
               for _ in range(3))
    rows.append(("micro/hstu_attn_interp_512",
                 _time(lambda: jax.block_until_ready(
                     ops.hstu_attention(q, k, v)), n=2),
                 "Pallas interpret mode (CPU oracle path)"))
    return rows


ALL_MICRO = [live_engine_ops, kernel_interpret]
