"""Benchmark harness: one function per paper figure/table.

Each function returns CSV rows ``(name, us_per_call, derived)`` where
``us_per_call`` is the headline latency (P99, in microseconds) or the
per-op cost, and ``derived`` is the paper-comparable headline (ratio,
max length, QPS...).  Cluster-scale numbers come from the discrete-event
simulator driven by the calibrated cost model (see EXPERIMENTS.md
§Calibration); all RelayGR state machines are the real implementations.

Paper targets being reproduced:
  Fig.11a  max supported sequence length (up to 1.5x baseline w/ DRAM)
  Fig.11b  ~2x concurrency at fixed P99
  Fig.11c  component breakdown: pre grows with L; load/rank stay low
  Fig.11d  SLO-compliant throughput (up to 3.6x w/ DRAM)
  Fig.12   remote fetch 100s of times local access
  Fig.13a-d scaling with sequence length; retrieval slack (~5x conc.)
  Fig.14a-d candidates / utilization / dim / depth extensions
  Table 1  psi = 32 MiB at 2K tokens (8L, 256d, fp32)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Tuple

import numpy as np

from benchmarks.capacity import (COST, HSTU, N_INST, SIM_S, SLO_MS,
                                 find_knee, fixed_stream, meets_slo,
                                 mode_config, run_point)
from repro.core.costmodel import GRCostModel, HardwareModel
from repro.core.runtime import (ClusterConfig, PipelineConfig, RelayConfig,
                                relay_config)
from repro.core.trigger import TriggerConfig
from repro.core.types import UserMeta
from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
from repro.models import get_config
from repro.serving.simulator import run_sim

# the sweep machinery now lives in benchmarks.capacity (the capacity
# harness shares it); these names are re-exports kept for the historical
# figure functions below
_fixed_stream = fixed_stream
_run = run_point


def _cfg(mode: str, L: int, cost=None) -> RelayConfig:
    """Per-mode deployment config — see ``capacity.mode_config`` for
    the mode glossary (this wrapper keeps the historical signature)."""
    return mode_config(mode, L)


def _meets_slo(s) -> bool:
    return meets_slo(s, SLO_MS)


def _meets_rank_budget(s) -> bool:
    """Ranking-stage criterion (Fig.13d style): the rank stage —
    queueing + load + rank-on-cache — stays within its own budget."""
    return s.get("n", 0) > 0 and s["rank_p99_ms"] <= 50.0


def _meets_ext_budget(s) -> bool:
    """Extension-study criterion (Fig.14c/d): relaxed rank budget so the
    scaled-up baselines stay measurable (the paper reports throughput
    curves, not SLO feasibility, for these sweeps)."""
    return s.get("n", 0) > 0 and s["rank_p99_ms"] <= 80.0


def _max_qps(mode, L, *, cost=None, lo=5, hi=None, pipeline=None,
             criterion=_meets_slo, n_items=512, refresh=None,
             dur=SIM_S, coarse=False) -> float:
    """Largest offered QPS meeting the SLO criterion (the shared
    geometric-expansion knee-finder, ``capacity.find_knee``: the upper
    probe doubles until the criterion fails, so there is no hard search
    cap to silently clip future throughput gains — ``hi`` merely seeds
    the first probe).

    Under the pipeline-SLO criterion the value is goodput (SLO-compliant
    completions/s); under stage-budget criteria it is raw completed
    throughput (the paper's Fig.13d/14 y-axes).  ``coarse`` widens the
    bisection tolerance (used by --quick CI smoke runs)."""
    key = "goodput_qps" if criterion is _meets_slo else "throughput_qps"

    def measure(q):
        return _run(mode, L, q, cost=cost, pipeline=pipeline,
                    n_items=n_items, refresh=refresh, dur=dur)

    return find_knee(measure, criterion, lo=lo, hi=hi, key=key,
                     coarse=coarse).best


# ---------------------------------------------------------------------------
# Fig. 11 — effectiveness
# ---------------------------------------------------------------------------

LENS_11A = [1024, 2048, 3072, 4096, 6144, 8192, 12288, 16384]


def fig11a_max_seq_len() -> List[Tuple]:
    rows = []
    maxlen = {}
    for mode in ("baseline", "relay", "relay_dram"):
        ok = 0
        for L in LENS_11A:
            s = _run(mode, L, qps=60)
            if _meets_slo(s):
                ok = L
            rows.append((f"fig11a/{mode}/L{L}", s["p99_ms"] * 1e3,
                         f"success={s['success_rate']:.4f}"))
        maxlen[mode] = ok
    base = max(maxlen["baseline"], 1)
    rows.append(("fig11a/max_len_ratio_relay", maxlen["relay"],
                 f"{maxlen['relay'] / base:.2f}x"))
    rows.append(("fig11a/max_len_ratio_relay_dram", maxlen["relay_dram"],
                 f"{maxlen['relay_dram'] / base:.2f}x (paper: up to 1.5x)"))
    return rows


def fig11b_tail_vs_concurrency() -> List[Tuple]:
    rows, L = [], 2048
    max_c = {}
    for mode in ("baseline", "relay", "relay_dram"):
        ok = 0
        for qps in (25, 50, 100, 150, 200, 300, 400):
            s = _run(mode, L, qps)
            if _meets_slo(s):
                ok = qps
            rows.append((f"fig11b/{mode}/qps{qps}", s["p99_ms"] * 1e3,
                         f"goodput={s['goodput_qps']:.0f}"))
        max_c[mode] = ok
    rows.append(("fig11b/concurrency_gain", max_c["relay"],
                 f"{max_c['relay'] / max(max_c['baseline'], 1):.1f}x "
                 "(paper: ~2x)"))
    return rows


def fig11c_breakdown() -> List[Tuple]:
    rows = []
    for L in (1024, 2048, 4096, 8192):
        pre = COST.pre_infer_ms(L)
        load = COST.dram_load_ms(L)
        rank = COST.rank_on_cache_ms(L, 64, 512)
        full = COST.full_rank_ms(L, 64, 512)
        rows.append((f"fig11c/L{L}", pre * 1e3,
                     f"pre={pre:.1f}ms load={load:.1f}ms rank={rank:.1f}ms "
                     f"baseline_full={full:.1f}ms"))
    return rows


def fig11d_slo_throughput() -> List[Tuple]:
    rows, L = [], 2048
    qps = {m: _max_qps(m, L) for m in ("baseline", "relay", "relay_dram")}
    for m, v in qps.items():
        rows.append((f"fig11d/{m}", 1e6 / max(v, 1e-9), f"{v:.0f} qps"))
    base = max(qps["baseline"], 1e-9)
    rows.append(("fig11d/throughput_gain_relay", qps["relay"],
                 f"{qps['relay'] / base:.2f}x"))
    rows.append(("fig11d/throughput_gain_relay_dram", qps["relay_dram"],
                 f"{qps['relay_dram'] / base:.2f}x (paper: up to 3.6x)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 12 — affinity is necessary
# ---------------------------------------------------------------------------


def fig12_local_vs_remote() -> List[Tuple]:
    rows = []
    for L in (1024, 2048, 4096, 8192, 16384):
        local_ms = COST.kv_bytes(L) / COST.hw.hbm_bw * 1e3
        remote_ms = COST.remote_fetch_ms(L)
        rows.append((f"fig12/L{L}", remote_ms * 1e3,
                     f"remote/local={remote_ms / local_ms:.0f}x "
                     "(paper: 100s of x)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 13 — scaled sequences
# ---------------------------------------------------------------------------


def fig13a_throughput_vs_len() -> List[Tuple]:
    rows = []
    collapse_len = None
    for L in (2048, 4096, 6144, 8192, 12288):
        for mode, refresh in (("baseline", 0.0), ("relay", 0.0),
                              ("relay_dram", 0.95)):
            q = _max_qps(mode, L)
            rows.append((f"fig13a/{mode}/L{L}", 1e6 / max(q, 1e-9),
                         f"{q:.0f} qps"))
            if mode == "baseline" and L >= 6144 and q < 10 \
                    and collapse_len is None:
                collapse_len = L
    rows.append(("fig13a/baseline_collapse",
                 collapse_len or 0,
                 "baseline <10qps beyond ~6K (paper: a few qps)"))
    return rows


def fig13b_components_long() -> List[Tuple]:
    rows = []
    for L in (4096, 8192, 15360):
        load = COST.dram_load_ms(L)
        rank = COST.rank_on_cache_ms(L, 64, 512)
        rows.append((f"fig13b/L{L}", load * 1e3,
                     f"load={load:.1f}ms rank={rank:.1f}ms "
                     "(paper@15K: load<20 rank<10)"))
    return rows


def fig13c_load_under_concurrency() -> List[Tuple]:
    rows = []
    for L in (4096, 8192):
        for qps in (50, 150):
            s = _run("relay_dram", L, qps, refresh=0.9)
            rows.append((f"fig13c/L{L}/qps{qps}", s["load_p99_ms"] * 1e3,
                         f"dram_hit={s['dram_hit']:.2f} "
                         f"full_baseline={COST.full_rank_ms(L, 64, 512):.0f}ms"))
    return rows


def fig13d_retrieval_slack() -> List[Tuple]:
    """Criterion: ranking-stage P99 <= 50 ms budget (the paper varies
    the retrieval budget independently of the pipeline SLO)."""
    rows, L = [], 3072
    conc = {}
    for ret_ms in (20, 60, 100):
        pp = PipelineConfig(retrieval_ms=ret_ms)
        conc[ret_ms] = _max_qps("relay", L, pipeline=pp,
                                criterion=_meets_ext_budget)
        rows.append((f"fig13d/relay/slack{ret_ms}ms", ret_ms * 1e3,
                     f"{conc[ret_ms]:.0f} qps"))
    base = _max_qps("baseline", L, criterion=_meets_ext_budget,
                    pipeline=PipelineConfig(retrieval_ms=100))
    rows.append(("fig13d/baseline/slack100ms", 100e3, f"{base:.0f} qps"))
    rows.append(("fig13d/slack_gain", conc[100],
                 f"{conc[100] / max(base, 1):.1f}x (paper: ~5x @100ms)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 14 — extensions
# ---------------------------------------------------------------------------


def fig14a_candidates() -> List[Tuple]:
    rows, L = [], 4096
    for items in (128, 512, 1024, 2048):
        r = COST.rank_on_cache_ms(L, 64, items)
        f = COST.full_rank_ms(L, 64, items)
        rows.append((f"fig14a/items{items}", r * 1e3,
                     f"rank_cached={r:.1f}ms full={f:.1f}ms "
                     "(paper: <10ms @2048)"))
    return rows


def fig14b_utilization() -> List[Tuple]:
    rows, L = [], 2048
    for mode, refresh in (("relay", 0.0), ("relay_dram", 0.95)):
        for qps in (50, 150, 250):
            s = _run(mode, L, qps, refresh=refresh)
            rows.append((f"fig14b/{mode}/qps{qps}",
                         s["special_util"] * 1e6,
                         f"util={s['special_util']:.2f} "
                         f"p99={s['p99_ms']:.0f}ms"))
    return rows


def _scaled_cost(dim=None, layers=None) -> GRCostModel:
    cfg = HSTU
    kw = {}
    hw = HardwareModel()
    if dim:
        kw.update(d_model=dim, d_ff=4 * dim,
                  n_heads=max(dim // 64, 1), head_dim=64)
        # sustained FLOP/s grows with GEMM width (cube utilization):
        # calibrated ^0.75 scaling, documented in EXPERIMENTS.md
        hw = HardwareModel(eff_flops=2e12 * (dim / 256) ** 0.75)
    if layers:
        kw.update(n_layers=layers)
    return GRCostModel(dataclasses.replace(cfg, **kw), hw)


def fig14c_dimension_scaling() -> List[Tuple]:
    rows, L = [], 2048
    per_dim = {}
    for dim in (256, 512, 1024):
        cost = _scaled_cost(dim=dim)
        q = {m: _max_qps(m, L, cost=cost, n_items=128,
                         criterion=_meets_ext_budget)
             for m in ("baseline", "relay", "relay_dram")}
        per_dim[dim] = q
        rows.append((f"fig14c/dim{dim}", 1e6 / max(q["relay"], 1e-9),
                     f"base={q['baseline']:.0f} relay={q['relay']:.0f} "
                     f"dram={q['relay_dram']:.0f} qps"))
    q = per_dim[1024]
    rows.append(("fig14c/gain@1024", q["relay"],
                 f"relay={q['relay'] / max(q['baseline'], 1):.1f}x "
                 f"dram={q['relay_dram'] / max(q['baseline'], 1):.1f}x "
                 "(paper: >=2x, ~3x)"))
    return rows


def fig14d_depth_scaling() -> List[Tuple]:
    rows, L = [], 2048
    per = {}
    for layers in (8, 16):
        cost = _scaled_cost(layers=layers)
        q = {m: _max_qps(m, L, cost=cost, criterion=_meets_ext_budget,
                         refresh=0.95 if m == "relay_dram" else None)
             for m in ("baseline", "relay", "relay_dram")}
        per[layers] = q
        rows.append((f"fig14d/layers{layers}",
                     1e6 / max(q["relay"], 1e-9),
                     f"base={q['baseline']:.0f} relay={q['relay']:.0f} "
                     f"dram={q['relay_dram']:.0f} qps"))
    g16 = per[16]["relay_dram"] / max(per[16]["baseline"], 1)
    drop = 1 - per[16]["relay_dram"] / max(per[8]["relay_dram"], 1e-9)
    rows.append(("fig14d/gain@16L", per[16]["relay_dram"],
                 f"{g16:.1f}x vs baseline (paper: >=4x); "
                 f"100%-hit depth-doubling drop={drop:.0%} (paper: ~14%)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 15 + Table 1 — generality & cache footprint
# ---------------------------------------------------------------------------


def fig15_generality() -> List[Tuple]:
    """Fig.15a: GR model variants on 910C; Fig.15b: NPU types with the
    Type-1 model.  Absolute numbers differ by up to an order of
    magnitude (as in the paper); the relay gain stays > 1 everywhere.
    Each point uses a request profile its hardware can serve at all
    (the paper likewise tunes per-deployment defaults)."""
    rows = []
    variants = {
        "type1_hstu": (_scaled_cost(), 2048, 512),
        "type2_hstu_rev": (GRCostModel(
            dataclasses.replace(HSTU, n_heads=8, head_dim=32)), 2048, 512),
        "type3_longer_rankmixer": (_scaled_cost(dim=512), 2048, 128),
    }
    for vname, (cost, L, items) in variants.items():
        q = {m: _max_qps(m, L, cost=cost, n_items=items,
                         criterion=_meets_ext_budget)
             for m in ("baseline", "relay")}
        gain = q["relay"] / max(q["baseline"], 1)
        rows.append((f"fig15a/{vname}/910c", 1e6 / max(q['relay'], 1e-9),
                     f"relay_gain={gain:.1f}x (>1 for all models)"))
    npus = {"ascend310": (HardwareModel(eff_flops=0.4e12), 1024, 64),
            "ascend910c": (HardwareModel(), 2048, 512)}
    for nname, (hw, L, items) in npus.items():
        c = GRCostModel(HSTU, hw)
        q = {m: _max_qps(m, L, cost=c, n_items=items,
                         criterion=_meets_ext_budget)
             for m in ("baseline", "relay")}
        gain = q["relay"] / max(q["baseline"], 1)
        rows.append((f"fig15b/type1/{nname}", 1e6 / max(q['relay'], 1e-9),
                     f"relay_gain={gain:.1f}x (>1 on both NPUs)"))
    return rows


def table1_kv_footprint() -> List[Tuple]:
    b = COST.kv_bytes(2048)
    return [("table1/kv_2k_8L_256d_fp32", b,
             f"{b / 2**20:.0f} MiB (paper: 32 MB)")]


# ---------------------------------------------------------------------------
# machine-readable perf headline (BENCH_relay.json)
# ---------------------------------------------------------------------------


def bench_relay_summary(quick: bool = False) -> Dict:
    """Per-mode perf headline for the repo's perf trajectory: P99,
    SLO-compliant throughput and hit rates at a fixed reference point
    (L=2048, 60 offered QPS), plus the bisected max SLO-compliant QPS
    when not in quick mode.  Written by ``benchmarks/run.py`` to
    ``BENCH_relay.json`` so successive PRs can diff serving performance.
    """
    L, qps = 2048, 60
    # workload provenance: the regression gate refuses to diff headlines
    # produced under mismatched workloads (seed / draw population /
    # arrival process), so a knob change can't masquerade as a perf win
    out: Dict[str, Dict] = {"meta": {
        "L": L, "offered_qps": qps, "slo_ms": SLO_MS, "sim_s": SIM_S,
        "seed": 0, "horizon": 10**9, "arrival": "poisson",
        "workload": "uniform"}}
    for mode in ("baseline", "relay", "relay_dram", "relay_batched",
                 "relay_paged", "relay_devpool", "relay_segments",
                 "relay_multihost", "relay_disagg", "relay_cold",
                 "relay_tenants"):
        s = _run(mode, L, qps)
        entry = {
            "p50_ms": round(s["p50_ms"], 3),
            "p99_ms": round(s["p99_ms"], 3),
            "rank_p99_ms": round(s["rank_p99_ms"], 3),
            "success_rate": round(s["success_rate"], 4),
            "goodput_qps": round(s["goodput_qps"], 1),
            "hbm_hit": round(s["hbm_hit"], 4),
            "dram_hit": round(s["dram_hit"], 4),
            "cold_hit": round(s.get("cold_hit", 0.0), 4),
            "miss": round(s["miss"], 4),
            "reused_frac": round(s["reused_frac"], 4),
        }
        # quick (CI smoke) still reports slo_qps — shorter sims and a
        # coarser bisection keep it cheap while preserving the fields
        # the workflow gate checks
        entry["slo_qps"] = round(
            _max_qps(mode, L, dur=4.0 if quick else SIM_S, coarse=quick),
            1)
        out[mode] = entry
    # tail-user probe: the cold tier only differentiates once admission
    # rate-limits (below the pool ceiling every admitted request
    # pre-infers and trivially hits HBM), so the headline includes the
    # reuse fraction PAST the knee — at 1.15x relay_segments' measured
    # slo_qps under the rapid-refresh workload — where rate-limited
    # returning users must be served out of the memory hierarchy.  The
    # regression gate requires relay_cold to beat relay_segments here:
    # hbm + dram + cold reuse, the tail users the DRAM-less modes
    # re-rank from scratch.
    q_tail = round(1.15 * out["relay_segments"]["slo_qps"], 1)
    for mode in ("relay_segments", "relay_cold"):
        s = _run(mode, L, q_tail, refresh=0.5,
                 dur=4.0 if quick else SIM_S)
        out[mode]["tail_qps"] = q_tail
        out[mode]["tail_reuse_frac"] = round(
            s["hbm_hit"] + s["dram_hit"] + s.get("cold_hit", 0.0), 4)
        out[mode]["tail_cold_hit"] = round(s.get("cold_hit", 0.0), 4)
    return out


ALL_FIGURES = [
    fig11a_max_seq_len, fig11b_tail_vs_concurrency, fig11c_breakdown,
    fig11d_slo_throughput, fig12_local_vs_remote, fig13a_throughput_vs_len,
    fig13b_components_long, fig13c_load_under_concurrency,
    fig13d_retrieval_slack, fig14a_candidates, fig14b_utilization,
    fig14c_dimension_scaling, fig14d_depth_scaling, fig15_generality,
    table1_kv_footprint,
]
