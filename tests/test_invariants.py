"""Cross-config invariant fuzz suite: ONE harness guarding EVERY mode.

Every PR so far added a deployment dimension — hosts, paged windows,
micro-batching, churn, and now disaggregated prefill with cross-host
psi shipping.  Each dimension shipped with its own tests, but nothing
guarded the *combinations*: a future PR could break paged + churn +
shipping without tripping a single suite.  This harness closes that
hole: hypothesis samples random cluster configs across the full matrix

    hosts x page_tokens x batched x churn events x prefill_hosts
    x segments (beyond-prefix span reuse over the paged window)
    x cold tier (host-local SSD / remote psi store under DRAM)
    x tenants (per-tenant partitions across every memory tier)

plus timed arrival streams (repeat visitors for reuse, uniques for
window pressure, mixed prefix lengths), runs the virtual-clock sim and
asserts the GLOBAL invariants on every run:

  * latency accounting — ``latency == sum(components)`` == rank-stage
    wall time, for every completed request;
  * cache conservation — ``inserts == live + evictions + handoffs``
    per instance, after any interleaving;
  * page conservation (paged windows) — ``pages_allocated ==
    pages_live + pages_freed`` and the free list never double-holds;
  * ``premature_evictions == 0`` under a correctly sized trigger,
    including across churn and in-flight shipments;
  * single ownership — no user psi resident on two instances' HBM, no
    DRAM copy in two expander tiers;
  * shipping conservation — ``shipped == landed + dropped`` with
    nothing left in flight after the drain;
  * tenant isolation (tenants > 1) — zero cross-tenant evictions,
    per-tenant byte accounting that matches the live set and never
    exceeds the quota, and zero per-tenant premature evictions.

Hypothesis-driven via the tests/_hyp.py shim (skips cleanly when
hypothesis is absent).
"""

import numpy as np

from _hyp import given, settings, st
from repro.core import (ClusterConfig, GRCostModel, TriggerConfig, UserMeta,
                        relay_config)
from repro.data.synthetic import segment_lens
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))

# correctly sized trigger for the fuzzed workload: kv_p99_len covers
# every sampled prefix, q_m derives from the true pre-infer cost, and
# the rate caps (Eqs. 1-3) keep the window under budget so admitted
# caches always survive to consumption
HBM = 2e9
PREFIX_LENS = (1024, 2048, 3072)


def _trigger() -> TriggerConfig:
    return TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5,
                         kv_p99_len=4096, hbm_bytes=HBM / 0.5, r1=0.5,
                         q_m=1e3 / COST.pre_infer_ms(max(PREFIX_LENS)))


CONFIGS = st.fixed_dictionaries({
    "hosts": st.integers(1, 3),
    "prefill_hosts": st.integers(0, 2),
    "page_tokens": st.sampled_from([0, 64]),
    "max_batch": st.sampled_from([0, 4]),
    "dram": st.sampled_from([0.0, 500e9]),
    # 150e6 is DELIBERATELY tiny (~4 psi): it forces DRAM LRU churn so
    # demotions/promotions actually fire inside the fuzzed streams
    "cold": st.sampled_from([0.0, 400e9]),
    "dram_small": st.booleans(),
    "churn": st.sampled_from(["none", "leave", "join", "leave-prefill"]),
    "qps": st.sampled_from([40.0, 120.0]),
    "n": st.integers(40, 80),
    "seed": st.integers(0, 10 ** 6),
    # beyond-prefix segment reuse rides the paged window only; the flag
    # is a no-op when page_tokens samples 0 (see _build)
    "segments": st.booleans(),
    # multi-tenant serving: tenants > 1 partitions every memory tier
    # and must uphold the isolation invariants under every combination
    "tenants": st.sampled_from([1, 2, 3]),
})


def _stream(n: int, qps: float, seed: int, tenants: int = 1):
    """Timed arrivals: ~half repeat visitors (reuse, DRAM cycling,
    shipping dedup), ~half uniques (window pressure, cold shipments).
    A user's prefix length is a function of the user — identical
    visits, like a real history — otherwise the same key legitimately
    caches through BOTH pools (short visit -> normal instance, long
    visit -> special) and single-ownership would be vacuously false.
    The tenant stamp is a pure function of the user id (no RNG draws),
    so the tenants axis never perturbs the sampled stream."""
    rng = np.random.default_rng(seed)
    pool = [1000 + i for i in range(6)]
    t, out = 0.0, []
    for _ in range(n):
        t += rng.exponential(1.0 / qps)
        uid = (int(rng.choice(pool)) if rng.random() < 0.5
               else int(rng.integers(0, 10 ** 9)))
        out.append((t, UserMeta(
            user_id=uid,
            prefix_len=PREFIX_LENS[uid % len(PREFIX_LENS)],
            tenant=uid % tenants,
            # inert annotation unless the config samples segments=True
            seg_lens=segment_lens(uid, 64))))
    return out


def _build(p) -> ClusterSim:
    # segments require a paged window; the sampled flag is a no-op on
    # the dense-store configs (other tests pass 5-key dicts — default
    # to off for them)
    segments = p.get("segments", False) and p["page_tokens"] > 0
    dram = p["dram"]
    if p.get("dram_small") and dram > 0:
        # shrink the expander to ~4 psi so LRU pressure (and, with a
        # cold tier, the demotion/promotion machinery) actually runs
        dram = 150e6
    cfg = relay_config(
        trigger=_trigger(),
        cluster=ClusterConfig(
            # hbm override: the non-vacuousness test shrinks the window
            # so returning users actually fall out of HBM (the LRU at
            # 2e9 holds ~59 psi — more than any recurring pool here,
            # which would leave the cold probe dead code)
            hbm_cache_bytes=p.get("hbm", HBM), dram_budget_bytes=dram,
            cold_budget_bytes=p.get("cold", 0.0),
            hosts=p["hosts"], prefill_hosts=p["prefill_hosts"],
            page_tokens=p["page_tokens"], max_batch=p["max_batch"],
            segments=segments, tenants=p.get("tenants", 1)))
    return ClusterSim(cfg, COST)


def _assert_tenant_partition(label: str, store) -> None:
    """Multi-tenant isolation invariants for any tiered store (HBM /
    DRAM expander / cold): nobody ever evicted across the partition,
    per-tenant byte accounting matches the live set exactly, no tenant
    exceeds its quota, and the per-tenant bytes sum to the store total.
    All inert (vacuously true) on untenanted stores."""
    assert store.stats.get("cross_tenant_evictions", 0) == 0, \
        f"{label}: cross-tenant eviction (isolation violated)"
    if getattr(store, "tenant_quota", None) is None:
        return
    live = {}
    for e in store.entries.values():
        live[e.tenant] = live.get(e.tenant, 0) + e.nbytes
    for t, quota in store.tenant_quota.items():
        used = store.tenant_used.get(t, 0)
        assert used == live.get(t, 0), \
            f"{label}: tenant {t} accounting {used} != live {live.get(t, 0)}"
        assert used <= quota, \
            f"{label}: tenant {t} over quota ({used} > {quota})"
    assert sum(store.tenant_used.values()) == store.used_bytes, \
        f"{label}: tenant partition does not sum to used_bytes"
    if store.tenant_stats is not None:
        for t, ts in store.tenant_stats.items():
            assert ts.get("premature_evictions", 0) == 0, \
                f"{label}: tenant {t} admitted psi died unconsumed: {ts}"


def _assert_invariants(sim: ClusterSim, n_arrivals: int) -> None:
    rt = sim.runtime
    assert not rt.events, "drain left events pending"
    assert len(rt.records) == n_arrivals, \
        f"lost requests: {len(rt.records)} != {n_arrivals}"

    # latency accounting: component sum IS the rank-stage wall time
    for r in rt.records:
        comp = r.queue_ms + r.pre_ms + r.load_ms + r.rank_ms
        wall = (r.t_done - r.t_rank_arrival) * 1e3
        assert abs(comp - wall) < 1e-6, \
            f"user {r.user_id}: components {comp} != wall {wall}"
        assert abs(r.e2e_ms - (r.t_done - r.t_arrival) * 1e3) < 1e-6
        for c in (r.queue_ms, r.pre_ms, r.load_ms, r.rank_ms):
            assert np.isfinite(c) and c >= 0.0

    owners_hbm, owners_dram, owners_cold, expanders = {}, {}, {}, {}
    for name, inst in rt.instances.items():
        # cache conservation through the eviction/handoff turnstiles
        hs = inst.hbm.stats
        assert hs["inserts"] == (inst.hbm.live_count + hs["evictions"]
                                 + hs["handoffs"]), \
            f"{name}: cache conservation broken: {hs}"
        assert hs["premature_evictions"] == 0, \
            f"{name}: admitted psi died unconsumed: {hs}"
        # page conservation (paged windows only)
        pool = getattr(inst.hbm, "pool", None)
        if pool is not None:
            assert pool.stats["pages_allocated"] == \
                pool.pages_live + pool.stats["pages_freed"], pool.stats
            assert len(set(pool._free)) == len(pool._free), \
                "free list double-holds a page"
            assert pool.free_pages + pool.pages_live == pool.n_pages
        # single ownership: psi resident on at most one instance
        for uid in inst.hbm.entries:
            assert uid not in owners_hbm, \
                f"user {uid} on {owners_hbm[uid]} AND {name}"
            owners_hbm[uid] = name
        _assert_tenant_partition(f"{name}/hbm", inst.hbm)
        expanders[id(inst.expander)] = inst.expander
    for exp in expanders.values():
        # DRAM tier conservation through every turnstile: LRU drops,
        # cold demotions, upward reloads, rebalance handoffs
        es = exp.stats
        assert es["inserts"] == (len(exp.entries) + es["evictions"]
                                 + es["demotions"] + es["handoffs"]
                                 + es["promotions"]), \
            f"DRAM conservation broken: {es}"
        for uid in exp.entries:
            assert uid not in owners_dram, \
                f"user {uid} in two DRAM tiers"
            owners_dram[uid] = id(exp)
        _assert_tenant_partition("dram", exp)

    # cold-tier conservation: every insert is live, evicted, handed
    # off, or promoted back up; every demotion landed or was dropped;
    # nothing is still on a cold link after the drain; no user's cold
    # copy lives in two stores
    cold = rt.stats()["cold"]
    assert cold["demotions"] == cold["demote_landed"] \
        + cold["demote_dropped"] + cold["demote_inflight"], cold
    assert cold["demote_inflight"] == 0, \
        f"demotion still on a cold link after drain: {cold}"
    assert cold["inflight"] == 0, cold
    all_stores = dict(rt.cold_stores)
    all_stores.update(rt._orphan_cold)
    for host, store in all_stores.items():
        cs = store.stats
        assert cs["inserts"] == (store.live_count + cs["evictions"]
                                 + cs["handoffs"] + cs["promotions"]), \
            f"{host}: cold conservation broken: {cs}"
        for uid in store.entries:
            assert uid not in owners_cold, \
                f"user {uid} cold-resident on {owners_cold[uid]} AND {host}"
            owners_cold[uid] = host
        _assert_tenant_partition(f"{host}/cold", store)
    for link in rt.cold_links.values():
        assert link["wait_ms"] >= 0.0 and link["bytes"] >= 0

    # shipping conservation: every shipment either landed or was
    # dropped by churn — nothing is still in the network after drain
    ship = rt.stats()["shipping"]
    assert ship["shipped"] + ship["forwarded"] >= ship["landed"]
    assert ship["shipped"] == ship["landed"] + ship["dropped"], ship
    assert ship["inflight"] == 0, ship
    for nic in rt.nics.values():
        assert nic["wait_ms"] >= 0.0 and nic["bytes"] >= 0

    # migrations never silently lose entries under the handoff policy
    assert rt.migration["dropped"] >= 0

    # multi-tenant rollup: the fleet-wide partition-violation total is
    # zero (the per-store checks above imply it; the rollup must agree)
    if rt.tenants > 1:
        roll = rt.stats()["tenants"]
        assert roll["cross_tenant_evictions"] == 0, roll


@given(CONFIGS)
@settings(max_examples=12, deadline=None)
def test_global_invariants_across_config_matrix(p):
    """Any sampled (hosts, prefill_hosts, page_tokens, batched, DRAM,
    churn, stream) combination upholds every global invariant."""
    sim = _build(p)
    arrivals = _stream(p["n"], p["qps"], p["seed"],
                       tenants=p.get("tenants", 1))
    t_mid = arrivals[len(arrivals) // 2][0]
    churn = p["churn"]
    if churn == "leave" and p["hosts"] < 2:
        churn = "join"                 # can't leave the last rank host
    if churn == "leave-prefill" and p["prefill_hosts"] == 0:
        churn = "join"                 # no prefill host to take down
    if churn == "leave":
        sim.runtime.schedule(t_mid, "host_leave", name="host-1")
    elif churn == "leave-prefill":
        # a departing prefill engine re-routes its queued side-path
        # work (to a surviving engine, or the rank owner when the pool
        # empties — where a local completion must still close the
        # shipment marker)
        sim.runtime.schedule(t_mid, "host_leave", name="prefill-host-0")
    elif churn == "join":
        sim.runtime.schedule(t_mid, "host_join", n_special=1, n_normal=1)
    sim.run(iter(arrivals))
    _assert_invariants(sim, len(arrivals))
    # the harness must not be vacuous: something was admitted
    assert any(i.hbm.stats["inserts"] > 0
               for i in sim.runtime.instances.values())


@given(st.integers(0, 10 ** 6), st.integers(1, 2))
@settings(max_examples=8, deadline=None)
def test_churn_with_inflight_shipments(seed, prefill_hosts):
    """The acceptance case the matrix only hits by chance: a rank host
    leaves at a moment chosen to overlap in-flight psi shipments; every
    copy on the wire re-routes (or drops, counted) and the invariants
    hold — no double ownership, nothing premature, nothing leaked."""
    rng = np.random.default_rng(seed)
    sim = _build({"hosts": 2, "prefill_hosts": prefill_hosts,
                  "page_tokens": 0, "max_batch": 0, "dram": 500e9})
    arrivals = []
    t = 0.0
    for i in range(60):
        t += rng.exponential(1.0 / 150.0)
        arrivals.append((t, UserMeta(user_id=int(rng.integers(0, 10 ** 9)),
                                     prefix_len=2048)))
    # admitted signals fire ~3 ms after arrival and ship ~30 ms later;
    # leaving right inside the stream guarantees wire overlap
    sim.runtime.schedule(arrivals[30][0] + 0.02, "host_leave",
                         name="host-1")
    sim.run(iter(arrivals))
    _assert_invariants(sim, len(arrivals))
    assert sim.runtime.stats()["shipping"]["shipped"] > 0, "vacuous"


def test_prefill_zero_is_not_disaggregated():
    """Guard the config contract: prefill_hosts=0 builds no prefill
    pool, no NIC serialization, and an all-zero shipping ledger."""
    sim = _build({"hosts": 2, "prefill_hosts": 0, "page_tokens": 0,
                  "max_batch": 0, "dram": 0.0})
    sim.run(iter(_stream(20, 60.0, 0)))
    rt = sim.runtime
    assert rt.prefill == [] and not rt.disagg and not rt.nic_serialize
    ship = rt.stats()["shipping"]
    assert all(v == 0 for v in ship.values()), ship


def test_cold_zero_builds_no_cold_tier():
    """Guard the config contract: cold_budget_bytes=0 builds no cold
    stores, wires no demote sinks or admission estimator, and leaves
    an all-zero cold ledger — the bit-identity precondition."""
    sim = _build({"hosts": 2, "prefill_hosts": 0, "page_tokens": 0,
                  "max_batch": 0, "dram": 500e9, "dram_small": True})
    sim.run(iter(_stream(30, 120.0, 1)))
    rt = sim.runtime
    assert not rt.cold_enabled
    assert rt.cold_stores == {} and rt._orphan_cold == {}
    assert rt.cold_links == {}
    assert rt.trigger.cold_estimator is None
    assert all(i.expander.demote_sink is None
               for i in rt.instances.values())
    cold = rt.stats()["cold"]
    assert all(v == 0 for k, v in cold.items() if k != "stores"), cold
    assert cold["stores"] == {}


def test_cold_tier_exercised_not_vacuous():
    """The fuzz matrix must actually reach the cold machinery: a tiny
    DRAM tier over a rapid-refresh stream demotes on LRU pressure and
    promotes on return visits, and the conservation invariants hold."""
    rng = np.random.default_rng(7)
    sim = _build({"hosts": 1, "prefill_hosts": 0, "page_tokens": 0,
                  "max_batch": 0, "dram": 500e9, "dram_small": True,
                  "cold": 400e9, "hbm": 300e6})
    pool = [1000 + i for i in range(60)]
    arrivals, t = [], 0.0
    for _ in range(300):
        t += rng.exponential(1.0 / 60.0)
        uid = (int(rng.choice(pool)) if rng.random() < 0.9
               else int(rng.integers(0, 10 ** 9)))
        arrivals.append((t, UserMeta(user_id=uid, prefix_len=2048)))
    sim.run(iter(arrivals))
    _assert_invariants(sim, len(arrivals))
    cold = sim.runtime.stats()["cold"]
    assert cold["demote_landed"] > 0, cold
    assert cold["promotions"] > 0, cold
    assert sim.runtime.summary()["cold_hit"] > 0.0


def test_single_tenant_builds_no_tenant_machinery():
    """Guard the bit-identity contract: tenants=1 (the default) builds
    untenanted stores everywhere — no quota maps, no per-tenant
    ledgers, no ``tenants`` block in the stats rollup."""
    sim = _build({"hosts": 2, "prefill_hosts": 0, "page_tokens": 64,
                  "max_batch": 0, "dram": 500e9, "cold": 400e9,
                  "dram_small": True})
    sim.run(iter(_stream(30, 60.0, 3)))
    rt = sim.runtime
    assert rt.tenants == 1
    for inst in rt.instances.values():
        assert inst.hbm.tenant_quota is None
        assert inst.hbm.tenant_stats is None
        assert inst.expander.tenant_quota is None
    for store in rt.cold_stores.values():
        assert store.tenant_quota is None
    assert "tenants" not in rt.stats()


def test_tenant_partition_exercised_not_vacuous():
    """The tenants axis must actually create pressure INSIDE a
    tenant's share: a small window split two ways forces same-tenant
    evictions in both partitions while every isolation invariant holds
    and the per-tenant ledgers populate on both sides."""
    rng = np.random.default_rng(11)
    sim = _build({"hosts": 1, "prefill_hosts": 0, "page_tokens": 0,
                  "max_batch": 0, "dram": 0.0, "tenants": 2,
                  "hbm": 300e6})
    pool = [1000 + i for i in range(60)]
    arrivals, t = [], 0.0
    for _ in range(200):
        t += rng.exponential(1.0 / 60.0)
        uid = (int(rng.choice(pool)) if rng.random() < 0.9
               else int(rng.integers(0, 10 ** 9)))
        arrivals.append((t, UserMeta(user_id=uid, prefix_len=2048,
                                     tenant=uid % 2)))
    sim.run(iter(arrivals))
    _assert_invariants(sim, len(arrivals))
    assert all(i.hbm.tenant_quota is not None
               for i in sim.runtime.instances.values())
    roll = sim.runtime.stats()["tenants"]
    for tid in (0, 1):
        assert roll["hbm"][tid]["inserts"] > 0, roll["hbm"]
        assert roll["hbm"][tid]["evictions"] > 0, roll["hbm"]
