"""Correctness contract for continuous micro-batching (the contract
promised by ``repro/serving/batching.py``): batched scores equal
per-request scores — across every ``BUCKETS`` boundary (n, n+1, exact
bucket), with mixed prefix lengths inside one group (padded-key
masking), and through the registered ``batched`` executor end-to-end
under ``RelayRuntime``, not just the raw ``BatchedRankExecutor``.

Also locks the runtime-side semantics: hit classification, the
``latency_ms == sum(components)`` invariant under batching, aggregator
slot scheduling, warmup, and the throughput ordering
relay_batched >= relay at equal hit rates.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (BatchingConfig, ClusterConfig, Executor,
                        GRCostModel, HitKind, TriggerConfig, UserMeta,
                        get_executor, relay_config)
from repro.core.executors import BatchedLiveExecutor
from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
from repro.models import build_model, get_config
from repro.serving.batching import (BUCKETS, BatchAggregator, PendingRank,
                                    bucket_of, pad_psi)
from repro.serving.simulator import ClusterSim, run_sim

CFG = get_config("hstu_gr", smoke=True)
COST = GRCostModel(CFG)
COST_FULL = GRCostModel(get_config("hstu_gr"))
N_ITEMS, INCR = 16, 8


@pytest.fixture(scope="module")
def live():
    """(model, params, store, batched executor) — one jit cache for the
    whole module."""
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=CFG.vocab, n_items=N_ITEMS, incr_len=INCR, max_len=512))
    ex = get_executor("batched")(
        model, params, store, cost=COST,
        batching=BatchingConfig(max_batch=4, max_wait_ms=2.0))
    return model, params, store, ex


def _work(meta, psi):
    return PendingRank(user_id=meta.user_id, psi=psi,
                       prefix_len=meta.prefix_len, meta=meta)


def _meta(uid, plen):
    return UserMeta(user_id=uid, prefix_len=plen, incr_len=INCR,
                    n_items=N_ITEMS)


# ---------------------------------------------------------------------------
# registry + protocol
# ---------------------------------------------------------------------------


def test_batched_executor_registered(live):
    assert get_executor("batched") is BatchedLiveExecutor
    _, _, _, ex = live
    assert isinstance(ex, Executor)           # protocol surface intact
    assert ex.batching.max_batch == 4         # runtime batching opt-in
    assert callable(ex.rank_group)


# ---------------------------------------------------------------------------
# batched == per-request, across bucket boundaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", [64, 128])
def test_batched_matches_per_request_at_bucket_boundaries(live, boundary):
    """n just-below, exactly-at, and just-above a BUCKETS edge: batched
    group scores bit-match the per-request rank_cached scores."""
    _, _, _, ex = live
    for base_uid, plens in ((10, (boundary - 1, boundary)),
                            (20, (boundary + 1,))):
        group, singles = [], []
        for i, plen in enumerate(plens):
            meta = _meta(base_uid + i, plen)
            psi, _, _ = ex.pre_infer(meta)
            s, _ = ex.rank_cached(meta, psi)
            singles.append(np.asarray(s)[0])
            group.append(_work(meta, psi))
        scores, ms = ex.rank_group(group)
        assert ms > 0
        for got, want in zip(scores, singles):
            np.testing.assert_array_equal(np.asarray(got), want)


def test_mixed_prefix_lengths_one_group_padded_keys_exact(live):
    """One bucket (256), psi tensors at different 64-grid lengths
    (192/256): zero-padded K rows must contribute exactly nothing."""
    model, params, _, ex = live
    group, singles = [], []
    for uid, plen in ((30, 129), (31, 200), (32, 256)):
        meta = _meta(uid, plen)
        psi, _, _ = ex.pre_infer(meta)
        s, _ = ex.rank_cached(meta, psi)
        singles.append(np.asarray(s)[0])
        group.append(_work(meta, psi))
    lens = {w.psi[0].shape[2] for w in group}
    assert lens == {192, 256}, "group must mix psi lengths to pad"
    scores, _ = ex.rank_group(group)
    for got, want in zip(scores, singles):
        np.testing.assert_array_equal(np.asarray(got), want)
    # padding is explicit and exact: manually padded psi reproduces the
    # batched member bit-for-bit through the unjitted model call
    w = group[0]
    kp, vp = pad_psi(jax.numpy, w.psi, 256)
    want = model.rank_with_cache(
        params, (kp, vp),
        jax.numpy.asarray(ex.store.short_term(w.user_id)[None]),
        jax.numpy.asarray(ex.store.candidates(w.user_id)[None]))
    np.testing.assert_allclose(np.asarray(scores[0]), np.asarray(want)[0],
                               atol=1e-5, rtol=1e-5)


def test_batched_full_rank_matches_per_request(live):
    """Miss-fallback members (psi=None) batch through full_rank and
    bit-match the per-request rank_full path."""
    _, _, _, ex = live
    group, singles = [], []
    for uid, plen in ((40, 100), (41, 127), (42, 65)):
        meta = _meta(uid, plen)
        s, _ = ex.rank_full(meta)
        singles.append(np.asarray(s)[0])
        group.append(_work(meta, None))
    scores, _ = ex.rank_group(group)
    for got, want in zip(scores, singles):
        np.testing.assert_array_equal(np.asarray(got), want)


def test_batch_axis_padding_is_row_independent(live):
    """A 3-deep group snaps to the 4-row grid by repeating row 0; the
    real members' scores must be unaffected — compare against the same
    group run as singletons."""
    _, _, _, ex = live
    metas = [_meta(50 + i, 70 + 7 * i) for i in range(3)]
    psis = [ex.pre_infer(m)[0] for m in metas]
    singles = [np.asarray(ex.rank_cached(m, p)[0])[0]
               for m, p in zip(metas, psis)]
    scores, _ = ex.rank_group([_work(m, p) for m, p in zip(metas, psis)])
    assert len(scores) == 3                   # pad row sliced off
    for got, want in zip(scores, singles):
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# aggregator semantics
# ---------------------------------------------------------------------------


def test_live_pre_infer_group_matches_per_request(live):
    """Batched pre-inference (one jitted prefill per prefill-grid
    group): each member's psi slice and byte size bit-match the psi its
    own per-request ``pre_infer`` would produce — so downstream rank
    scores cannot diverge between the batched and per-user side paths."""
    _, _, _, ex = live
    metas = [_meta(50 + i, plen) for i, plen in enumerate((100, 128, 65))]
    outs, ms = ex.pre_infer_group(metas)
    assert ms > 0 and len(outs) == len(metas)
    for meta, (psi, nbytes) in zip(metas, outs):
        want_psi, want_nbytes, _ = ex.pre_infer(meta)
        assert nbytes == want_nbytes
        for got, want in zip(psi, want_psi):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_aggregator_key_separates_kinds_and_buckets():
    agg = BatchAggregator(BatchingConfig(max_batch=8, max_wait_ms=5.0))
    cached = PendingRank(1, ("psi",), 100, incr_len=8, n_items=16)
    full = PendingRank(2, None, 100, incr_len=8, n_items=16)
    other_bucket = PendingRank(3, ("psi",), 200, incr_len=8, n_items=16)
    for p in (cached, full, other_bucket):
        assert agg.add(p, now=0.0) is None
    assert len(agg.queues) == 3               # never co-batched
    assert agg.pending == 3
    g = agg.take_for(cached)
    assert [p.user_id for p in g] == [1]


def test_aggregator_boundary_lengths_group_exactly():
    agg = BatchAggregator(BatchingConfig(max_batch=8, max_wait_ms=5.0))
    for b in BUCKETS[:4]:
        agg.add(PendingRank(b, ("psi",), b, incr_len=8, n_items=16), 0.0)
        agg.add(PendingRank(b + 1, ("psi",), b + 1, incr_len=8,
                            n_items=16), 0.0)
    # n lands in bucket(n); n+1 spills to the next bucket
    assert len(agg.queues) == 5
    g = agg.take_oldest()
    assert [p.user_id for p in g] == [BUCKETS[0]]


def test_aggregator_take_leaves_overflow_queued():
    agg = BatchAggregator(BatchingConfig(max_batch=2, max_wait_ms=5.0))
    got = None
    for uid in range(5):
        r = agg.add(PendingRank(uid, ("psi",), 100, incr_len=8,
                                n_items=16), now=uid * 1e-4)
        got = got or r
    assert [p.user_id for p in got] == [0, 1]
    assert agg.pending == 1                   # 2,3 flushed at max; 4 left
    assert agg.stats["max_seen_batch"] == 2


# ---------------------------------------------------------------------------
# RelayRuntime drives the batched executor end-to-end
# ---------------------------------------------------------------------------


def test_runtime_drives_batched_executor_end_to_end(live):
    """A burst of same-bucket users through the full relay: batches form,
    every admitted request scores identically to an out-of-band
    per-request call, and the latency invariant survives batching."""
    _, _, _, ex = live
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=2, r2=0.5,
                              rank_p99_budget_ms=50.0),
        cluster=ClusterConfig(m_slots=2))
    svc_cost = GRCostModel(CFG)
    from repro.core import RelayGRService
    svc = RelayGRService(cfg, svc_cost, executor_factory=lambda name: ex)
    rt = svc.runtime
    metas = [_meta(1000 + i, 200 + 8 * i) for i in range(6)]
    results = []
    for i, meta in enumerate(metas):
        rt.schedule(0.001 * i, "arrival", meta=meta, sink=results.append)
    rt.drain()
    assert len(results) == len(metas)
    batch_stats = [i.batcher.stats for i in svc.instances.values()
                   if i.batcher is not None and i.batcher.stats["requests"]]
    assert batch_stats, "no instance batched anything"
    assert sum(s["requests"] for s in batch_stats) == len(metas)
    for r, rec in zip(sorted(results, key=lambda r: r.user_id),
                      sorted(rt.records, key=lambda c: c.user_id)):
        assert r.latency_ms == pytest.approx(sum(r.components.values()),
                                             abs=1e-9)
        assert rec.rank_ms == r.components["rank"] > 0.0
        assert np.isfinite(np.asarray(r.scores, np.float32)).all()
        meta = metas[r.user_id - 1000]
        if r.hit in (HitKind.HBM_HIT, HitKind.DRAM_HIT):
            psi, _, _ = ex.pre_infer(meta)
            want, _ = ex.rank_cached(meta, psi)
        else:
            want, _ = ex.rank_full(meta)
        np.testing.assert_array_equal(np.asarray(r.scores),
                                      np.asarray(want)[0])


def test_batch_grid_never_exceeds_max_batch(live):
    _, _, _, ex = live
    odd = BatchedLiveExecutor(ex.model, ex.params, ex.store, cost=COST,
                              batching=BatchingConfig(max_batch=6))
    assert [odd._batch_grid(n) for n in (1, 2, 3, 5, 6)] == [1, 2, 4, 6, 6]
    assert all(odd._batch_grid(n) <= 6 for n in range(1, 7))


def test_warmup_precompiles_and_dedups(live):
    _, _, _, ex = live
    done = ex.warmup([70, 129], batch_sizes=(1, 3), incr_len=INCR,
                     n_items=N_ITEMS)
    # batch 3 snaps to the 4-row grid; 70 -> bucket 128, 129 -> 256
    assert set(done) == {(128, 1, INCR, N_ITEMS), (128, 4, INCR, N_ITEMS),
                         (256, 1, INCR, N_ITEMS), (256, 4, INCR, N_ITEMS)}
    assert ex.warmup([70, 129], batch_sizes=(1, 3), incr_len=INCR,
                     n_items=N_ITEMS) == []   # already warm


def test_warmup_respects_bucket_guard(live):
    _, _, _, ex = live
    guarded = dataclasses.replace(ex.batching, max_buckets_live=1)
    ex2 = BatchedLiveExecutor(ex.model, ex.params, ex.store, cost=COST,
                              batching=guarded)
    done = ex2.warmup([400, 100, 90, 70], batch_sizes=(1,),
                      incr_len=INCR, n_items=N_ITEMS)
    assert {k[0] for k in done} == {128}      # the traffic-dominant bucket


# ---------------------------------------------------------------------------
# sim mirror: throughput ordering at equal hit rates
# ---------------------------------------------------------------------------


def _sim_cfg(max_batch, m_slots=5):
    return relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8, kv_p99_len=2048,
                              hbm_bytes=8e9, r1=0.5, t_life_s=0.5),
        cluster=ClusterConfig(hbm_cache_bytes=4e9, dram_budget_bytes=0.0,
                              max_batch=max_batch, batch_wait_ms=2.0,
                              m_slots=m_slots))


def _stream(qps, dur, seed=0):
    rng = np.random.default_rng(seed)
    t = 0.0
    while t < dur:
        t += rng.exponential(1.0 / qps)
        yield t, UserMeta(user_id=int(rng.integers(0, 10 ** 9)),
                          prefix_len=2048)


def test_relay_batched_throughput_geq_relay_at_equal_hit_rates():
    plain = run_sim(_sim_cfg(0), COST_FULL, _stream(520, 5.0))
    batched = run_sim(_sim_cfg(8), COST_FULL, _stream(520, 5.0))
    assert batched["hbm_hit"] == pytest.approx(plain["hbm_hit"], abs=0.05)
    assert batched["miss"] == pytest.approx(plain["miss"], abs=0.05)
    assert batched["throughput_qps"] >= plain["throughput_qps"]
    assert batched["rank_p99_ms"] <= plain["rank_p99_ms"]


def test_batched_sim_groups_share_launch_cost():
    """Co-batched members report the same rank component — the group
    wall time — and the cost model's batched_rank_ms shape holds.
    One model slot per instance: batching is work-conserving, so depth
    only builds while slots are contended."""
    cfg = _sim_cfg(8, m_slots=1)
    sim = ClusterSim(cfg, COST_FULL)
    meta = [(1e-4 * i, UserMeta(user_id=5000 + i, prefix_len=2048))
            for i in range(12)]
    sim.run(iter(meta))
    assert len(sim.records) == 12
    by_rank = {}
    for r in sim.records:
        by_rank.setdefault(round(r.rank_ms, 9), []).append(r)
        assert r.rank_ms > 0
    deep = max(len(v) for v in by_rank.values())
    mb = max(i.batcher.stats["max_seen_batch"]
             for i in sim.instances.values() if i.batcher is not None)
    assert mb > 1, "burst never formed a batch"
    assert deep > 1, "co-batched members should share one rank latency"
    solo = COST_FULL.rank_on_cache_ms(2048, 64, 512)
    assert COST_FULL.batched_rank_ms([solo] * 4) == pytest.approx(
        solo * (1 + 3 * COST_FULL.batch_factor))
    assert COST_FULL.batched_rank_ms([]) == 0.0
