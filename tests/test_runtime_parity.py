"""Live-mode / sim-mode parity: both adapters drive ONE state machine.

The acceptance contract for the RelayRuntime refactor: for a fixed
seeded request stream, the live-path adapter (``RelayGRService.submit``,
wall clock, per-request drain) and the virtual-clock adapter
(``ClusterSim.run``, global drain) must produce identical per-request
``HitKind`` sequences and identical latency-component breakdowns —
proving the relay-race lifecycle exists exactly once in the codebase.

Also covers: the ``submit`` latency-consistency regression
(``latency_ms == sum(components.values())``), the legacy config shims,
``relay_config`` field routing, and the executor/policy registries.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (ClusterConfig, Executor, GRCostModel, HitKind,
                        RelayConfig, RelayGRService, SimExecutor,
                        TriggerConfig, UserMeta, relay_config)
from repro.core.engine import RankingInstance
from repro.core.policies import make_trigger
from repro.core.runtime import InstanceRuntime, as_relay_config
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))

# HBM window of ~2 psi entries per instance at L=4096 (~64 MiB each)
# plus a throttled admission bucket (q_m=0.1): repeat visitors cycle
# HBM -> DRAM, and rate-limited revisits take the rank-path DRAM reload,
# so the trace exercises every HitKind, not just the easy HBM path.
PARITY_CFG = relay_config(
    trigger=TriggerConfig(n_instances=5, r2=0.4, kv_p99_len=4096, q_m=0.1),
    cluster=ClusterConfig(hbm_cache_bytes=1.5e8, dram_budget_bytes=500e9))


def _arrivals(n=60, seed=0):
    """Seeded stream, spaced so each request's event cascade completes
    before the next arrival — the regime where per-request drain (live)
    and global drain (sim) must be indistinguishable."""
    rng = np.random.default_rng(seed)
    pool = [100 + i for i in range(4)]          # repeat visitors
    out = []
    for i in range(n):
        t = 1.0 * (i + 1)
        if rng.random() > 0.8:
            meta = UserMeta(user_id=int(rng.integers(0, 50)), prefix_len=64)
        else:
            meta = UserMeta(user_id=pool[int(rng.integers(0, len(pool)))],
                            prefix_len=4096)
        out.append((t, meta))
    return out


# ---------------------------------------------------------------------------
# the parity contract
# ---------------------------------------------------------------------------


def test_live_and_sim_traces_identical():
    svc = RelayGRService(PARITY_CFG, COST)
    live_results = [svc.submit(meta, now=t) for t, meta in _arrivals()]

    sim = ClusterSim(PARITY_CFG, COST)
    sim.run(iter(_arrivals()))

    live_recs, sim_recs = svc.runtime.records, sim.runtime.records
    assert len(live_recs) == len(sim_recs) == len(live_results)
    for a, b, r in zip(live_recs, sim_recs, live_results):
        assert a.user_id == b.user_id
        assert a.hit == b.hit == r.hit.value
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            assert getattr(a, f) == pytest.approx(getattr(b, f), abs=1e-9), \
                f"component {f} diverged for user {a.user_id}"
        assert a.e2e_ms == pytest.approx(b.e2e_ms, abs=1e-9)

    kinds = {r.hit for r in live_recs}
    assert {HitKind.HBM_HIT.value, HitKind.DRAM_HIT.value,
            HitKind.MISS_FALLBACK.value} <= kinds, \
        f"parity trivially true: workload only produced {kinds}"


def test_latency_equals_component_sum_and_wall_time():
    """Accounting invariant in both modes: latency_ms is exactly the
    component sum, which is exactly the rank-stage wall time."""
    svc = RelayGRService(PARITY_CFG, COST)
    results = [svc.submit(meta, now=t) for t, meta in _arrivals()]
    for r, rec in zip(results, svc.runtime.records):
        assert r.latency_ms == pytest.approx(
            sum(r.components.values()), abs=1e-9)
        assert r.latency_ms == pytest.approx(
            (rec.t_done - rec.t_rank_arrival) * 1e3, abs=1e-6)


def test_submit_latency_includes_pre_component():
    """Regression (former RelayGRService.submit bug): components['pre']
    was bolted on after latency_ms had been summed.  Now the runtime
    recomputes: an admitted long-sequence request whose pre-infer
    outlives the retrieval slack reports pre > 0 AND a consistent sum."""
    svc = RelayGRService(
        relay_config(trigger=TriggerConfig(n_instances=5, r2=0.4)), COST)
    meta = UserMeta(user_id=7, prefix_len=4096)
    r = svc.submit(meta, now=0.0)
    assert r.hit == HitKind.HBM_HIT          # relay worked
    assert r.components["pre"] > 0.0         # rank parked on its psi
    assert r.latency_ms == pytest.approx(sum(r.components.values()),
                                         abs=1e-9)


def test_manual_stage_api_unchanged():
    """The stage-level API (tests/ablations drive) composes the same
    kernels: pre-infer delivered out of band -> ranking hits HBM with a
    zero pre component (psi was ready before ranking arrived)."""
    svc = RelayGRService(
        relay_config(trigger=TriggerConfig(n_instances=5, r2=0.4)), COST)
    meta = UserMeta(user_id=11, prefix_len=4096)
    sig = svc.on_retrieval(meta, now=0.0)
    assert sig is not None
    svc.deliver_pre_infer(sig, now=0.0)
    r = svc.on_rank(meta, now=0.1)
    assert r.hit == HitKind.HBM_HIT
    assert r.components["pre"] == 0.0
    assert r.latency_ms == pytest.approx(sum(r.components.values()))


def test_rank_reload_followers_park_and_hit():
    """Single-flight contract on the rank path: a second rank request
    arriving while the same user's DRAM->HBM reload is in flight parks
    and then hits HBM — it must not fall back to full inference."""
    from repro.core.cache import CacheEntry
    cfg = relay_config(trigger=TriggerConfig(n_instances=5, r2=0.4),
                       cluster=ClusterConfig(trigger_policy="never"))
    sim = ClusterSim(cfg, COST)
    uid = 42
    target = sim.runtime.router.ring.route(uid)
    sim.instances[target].expander.spill(
        CacheEntry(uid, "psi", COST.kv_bytes(4096), 0.0, consumed=True,
                   prefix_len=4096))
    meta = UserMeta(user_id=uid, prefix_len=4096)
    sim.run([(0.0, meta), (0.001, meta)])     # 1ms apart, reload ~3.4ms
    hits = [r.hit for r in sim.records]
    assert hits == [HitKind.DRAM_HIT.value, HitKind.HBM_HIT.value]
    reloads = sum(i.expander.stats["reloads"]
                  for i in sim.instances.values())
    assert reloads == 1


def test_instances_share_one_implementation():
    """Both adapters schedule the same InstanceRuntime objects — the
    legacy RankingInstance name IS the runtime instance class."""
    assert RankingInstance is InstanceRuntime
    sim = ClusterSim(PARITY_CFG, COST)
    svc = RelayGRService(PARITY_CFG, COST)
    for pool in (sim.instances, svc.instances):
        assert all(isinstance(i, InstanceRuntime) for i in pool.values())


# ---------------------------------------------------------------------------
# the parity contract under the batched executor
# ---------------------------------------------------------------------------


def _batched_cfg(m_slots: int) -> RelayConfig:
    return dataclasses.replace(
        PARITY_CFG,
        cluster=dataclasses.replace(PARITY_CFG.cluster, m_slots=m_slots,
                                    max_batch=4, batch_wait_ms=2.0),
        trigger=dataclasses.replace(PARITY_CFG.trigger, m_slots=m_slots))


@pytest.mark.parametrize("m_slots", [1, 5])
def test_batched_executor_live_and_sim_traces_identical(m_slots):
    """The parity sweep extends to the batched executor: both adapters
    default to a batching-enabled SimExecutor when max_batch is set, and
    for the spaced stream the traces must stay identical — same hit/miss
    sequence, finite components, latency_ms == sum(components)."""
    cfg = _batched_cfg(m_slots)
    svc = RelayGRService(cfg, COST)
    live_results = [svc.submit(meta, now=t) for t, meta in _arrivals()]

    sim = ClusterSim(cfg, COST)
    sim.run(iter(_arrivals()))

    live_recs, sim_recs = svc.runtime.records, sim.runtime.records
    assert len(live_recs) == len(sim_recs) == len(live_results)
    for a, b, r in zip(live_recs, sim_recs, live_results):
        assert a.user_id == b.user_id
        assert a.hit == b.hit == r.hit.value
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            va, vb = getattr(a, f), getattr(b, f)
            assert np.isfinite(va) and va >= 0.0
            assert va == pytest.approx(vb, abs=1e-9), \
                f"component {f} diverged for user {a.user_id}"
        assert r.latency_ms == pytest.approx(
            sum(r.components.values()), abs=1e-9)
        assert r.latency_ms == pytest.approx(
            (a.t_done - a.t_rank_arrival) * 1e3, abs=1e-6)
    kinds = {r.hit for r in live_recs}
    # m_slots=1 throttles admission (Eq. 3) below the DRAM-reuse rate,
    # so only the 5-slot sweep must exercise every HitKind
    want = ({HitKind.HBM_HIT.value, HitKind.MISS_FALLBACK.value}
            if m_slots == 1 else
            {HitKind.HBM_HIT.value, HitKind.DRAM_HIT.value,
             HitKind.MISS_FALLBACK.value})
    assert want <= kinds, \
        f"parity trivially true: workload only produced {kinds}"
    for rt in (svc.runtime, sim.runtime):
        assert all(i.batcher is not None for i in rt.instances.values())


def test_batched_matches_unbatched_trace_when_uncontended():
    """Work-conserving batching: with free slots the group of one
    launches immediately in the already-held slot, so the spaced-stream
    trace is bit-identical to the unbatched executor's."""
    plain = ClusterSim(PARITY_CFG, COST)
    plain.run(iter(_arrivals()))
    batched = ClusterSim(_batched_cfg(5), COST)
    batched.run(iter(_arrivals()))
    assert len(plain.records) == len(batched.records)
    for a, b in zip(plain.records, batched.records):
        assert (a.user_id, a.hit) == (b.user_id, b.hit)
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            assert getattr(a, f) == pytest.approx(getattr(b, f), abs=1e-9)
        assert a.e2e_ms == pytest.approx(b.e2e_ms, abs=1e-9)


# ---------------------------------------------------------------------------
# the parity contract under disaggregated prefill
# ---------------------------------------------------------------------------


def _disagg_cfg(prefill_hosts: int, hosts: int = 2) -> RelayConfig:
    return dataclasses.replace(
        PARITY_CFG,
        cluster=dataclasses.replace(PARITY_CFG.cluster, hosts=hosts,
                                    prefill_hosts=prefill_hosts))


@pytest.mark.parametrize("prefill_hosts", [1, 2])
def test_disagg_live_and_sim_traces_identical(prefill_hosts):
    """Disaggregated prefill is one more deployment shape of the SAME
    state machine: for the spaced parity stream, live (per-request
    drain) and sim (global drain) must agree on every hit kind and
    every latency component — including the psi shipments riding the
    NIC fabric between the drains."""
    cfg = _disagg_cfg(prefill_hosts)
    svc = RelayGRService(cfg, COST)
    live_results = [svc.submit(meta, now=t) for t, meta in _arrivals()]

    sim = ClusterSim(cfg, COST)
    sim.run(iter(_arrivals()))

    live_recs, sim_recs = svc.runtime.records, sim.runtime.records
    assert len(live_recs) == len(sim_recs) == len(live_results)
    for a, b, r in zip(live_recs, sim_recs, live_results):
        assert a.user_id == b.user_id
        assert a.hit == b.hit == r.hit.value
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            assert getattr(a, f) == pytest.approx(getattr(b, f), abs=1e-9), \
                f"component {f} diverged for user {a.user_id}"
        assert r.latency_ms == pytest.approx(
            sum(r.components.values()), abs=1e-9)
        assert a.e2e_ms == pytest.approx(b.e2e_ms, abs=1e-9)
    # both modes actually exercised the split: psi shipped cross-host,
    # and their shipping ledgers agree entry for entry
    for rt in (svc.runtime, sim.runtime):
        ship = rt.stats()["shipping"]
        assert ship["shipped"] > 0 and ship["inflight"] == 0
    assert svc.runtime.stats()["shipping"] == sim.runtime.stats()["shipping"]


def test_prefill_hosts_zero_is_bit_identical():
    """The regression case from the acceptance criteria: with
    prefill_hosts=0 the new code paths must not perturb a single trace
    — hit kinds, components and wall times equal the plain PARITY_CFG
    deployment bit for bit, and the shipping/NIC machinery stays
    silent."""
    plain = ClusterSim(PARITY_CFG, COST)
    plain.run(iter(_arrivals()))
    explicit = ClusterSim(
        dataclasses.replace(
            PARITY_CFG,
            cluster=dataclasses.replace(PARITY_CFG.cluster,
                                        prefill_hosts=0,
                                        nic_serialize=None)),
        COST)
    explicit.run(iter(_arrivals()))
    assert len(plain.records) == len(explicit.records)
    for a, b in zip(plain.records, explicit.records):
        assert (a.user_id, a.hit) == (b.user_id, b.hit)
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            assert getattr(a, f) == getattr(b, f)
        assert a.e2e_ms == b.e2e_ms
        assert a.t_done == b.t_done
    ship = explicit.runtime.stats()["shipping"]
    assert all(v == 0 for v in ship.values())
    assert explicit.runtime.nics == {}


# ---------------------------------------------------------------------------
# RelayConfig + deprecation shims
# ---------------------------------------------------------------------------


def test_relay_config_routes_fields_to_subconfigs():
    cfg = relay_config(relay_enabled=False, retrieval_ms=10.0, r2=0.3)
    assert cfg.cluster.relay_enabled is False
    assert cfg.pipeline.retrieval_ms == 10.0
    assert cfg.trigger.r2 == 0.3
    with pytest.raises(TypeError):
        relay_config(definitely_not_a_field=1)
    # a field declared by several sub-configs is set on ALL of them, so
    # the trigger's Eq.3 capacity math always matches the real slots
    cfg = relay_config(m_slots=2)
    assert cfg.cluster.m_slots == 2
    assert cfg.trigger.m_slots == 2


def test_legacy_service_config_shim():
    from repro.core.service import ServiceConfig
    with pytest.warns(DeprecationWarning):
        sc = ServiceConfig(hbm_cache_bytes=1e9, long_seq_threshold=2048)
    rc = as_relay_config(sc)
    assert isinstance(rc, RelayConfig)
    assert rc.cluster.hbm_cache_bytes == 1e9
    assert rc.cluster.long_seq_threshold == 2048
    svc = RelayGRService(sc, COST)           # still accepted everywhere
    assert svc.cfg.cluster.hbm_cache_bytes == 1e9


def test_legacy_sim_config_shim():
    from repro.serving.simulator import SimConfig
    with pytest.warns(DeprecationWarning):
        c = SimConfig(relay_enabled=False, m_slots=3)
    rc = as_relay_config(c)
    assert rc.cluster.relay_enabled is False
    assert rc.cluster.m_slots == 3
    assert rc.trigger.n_instances == 10      # legacy default preserved


# ---------------------------------------------------------------------------
# executor + policy registries
# ---------------------------------------------------------------------------


def test_executor_protocol_and_registry():
    from repro.core.executors import (BatchedLiveExecutor, executor_names,
                                      get_executor)
    assert {"sim", "live", "batched"} <= set(executor_names())
    ex = get_executor("sim")(COST)
    assert isinstance(ex, SimExecutor) and isinstance(ex, Executor)
    assert get_executor("batched") is BatchedLiveExecutor
    with pytest.raises(KeyError):
        get_executor("warp-drive")


def test_trigger_policy_registry():
    short = UserMeta(user_id=1, prefix_len=64)
    seq = make_trigger("sequence-aware", TriggerConfig(), COST)
    assert not seq.admit(short, "i0", 0.0).admitted
    allp = make_trigger("admit-all", TriggerConfig(), COST)
    assert allp.admit(short, "i0", 0.0).admitted
    never = make_trigger("never", TriggerConfig(), COST)
    assert not never.admit(UserMeta(user_id=2, prefix_len=8192),
                           "i0", 0.0).admitted
    with pytest.raises(KeyError):
        make_trigger("nope", TriggerConfig(), COST)


def test_random_router_policy_breaks_affinity():
    """Pluggability proof: swapping one config string removes the
    producer/consumer rendezvous and the relay degrades to fallbacks."""
    cfg = relay_config(trigger=TriggerConfig(n_instances=10, r2=0.5),
                       cluster=ClusterConfig(router_policy="random", seed=3))
    svc = RelayGRService(cfg, COST)
    rng = np.random.default_rng(0)
    hits = 0
    n = 120
    for i in range(n):
        meta = UserMeta(user_id=int(rng.integers(0, 10**9)),
                        prefix_len=4096)
        sig = svc.on_retrieval(meta, now=i * 0.05)
        if sig is not None:
            svc.deliver_pre_infer(sig, now=i * 0.05)
        r = svc.on_rank(meta, now=i * 0.05 + 1e-3)
        hits += r.hit in (HitKind.HBM_HIT, HitKind.DRAM_HIT)
    # 5 special instances -> ~1/5 chance of accidental rendezvous
    assert hits / n < 0.5


# ---------------------------------------------------------------------------
# beyond-prefix segment reuse: disabled configs are trace-identical
# ---------------------------------------------------------------------------


def _seg_metas(kind):
    """A fixed stream over 7 repeat users: ``kind`` selects whether the
    metadata carries real segment annotations, empty ones, or none."""
    seg = {"none": lambda u: (), "empty": lambda u: (),
           "real": lambda u: (24, 16)}[kind]
    return [(i * 0.02, UserMeta(user_id=10 + (i % 7), prefix_len=2048,
                                seg_lens=seg(i)))
            for i in range(40)]


def _seg_cfg(segments):
    return relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.4, kv_p99_len=4096),
        cluster=ClusterConfig(hbm_cache_bytes=4e9, page_tokens=64,
                              segments=segments))


def _seg_trace(segments, kind):
    sim = ClusterSim(_seg_cfg(segments), COST)
    s = sim.run(iter(_seg_metas(kind)))
    trace = [(r.user_id, r.hit, r.e2e_ms, r.queue_ms, r.pre_ms,
              r.load_ms, r.rank_ms) for r in sim.records]
    return trace, s


def test_segments_disabled_is_trace_identical():
    """Parity discipline (same as hosts=1 / page_tokens=0): with the
    segments flag OFF, seg_lens annotations on the stream are inert;
    with the flag ON but no annotations, every path degenerates to
    prefix-only.  Both must match the baseline trace bit-for-bit."""
    base, s0 = _seg_trace(False, "none")
    annotated, s1 = _seg_trace(False, "real")
    empty, s2 = _seg_trace(True, "empty")
    assert annotated == base
    assert empty == base
    assert s1 == s0 and s2 == s0


def test_segments_enabled_raises_reused_fraction():
    """The point of the mode: same stream, same window — segment reuse
    strictly raises the reused-token fraction without losing hits."""
    base, s0 = _seg_trace(False, "real")
    segd, s1 = _seg_trace(True, "real")
    assert s1["reused_frac"] > s0["reused_frac"]
    assert s1["hbm_hit"] >= s0["hbm_hit"]
    # hit classification unchanged per request
    assert [t[1] for t in segd] == [t[1] for t in base]


def test_segments_require_paged_window():
    with pytest.raises(ValueError):
        ClusterSim(relay_config(
            trigger=TriggerConfig(n_instances=5, r2=0.4),
            cluster=ClusterConfig(segments=True)), COST)


# ---------------------------------------------------------------------------
# multi-tenant bit-identity
# ---------------------------------------------------------------------------


def _tenant_trace(tenants, stamp):
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.4, kv_p99_len=4096,
                              q_m=0.1),
        cluster=ClusterConfig(hbm_cache_bytes=1.5e8,
                              dram_budget_bytes=500e9, tenants=tenants))
    arrivals = _arrivals()
    if stamp:
        arrivals = [(t, dataclasses.replace(m, tenant=m.user_id % 2))
                    for t, m in arrivals]
    sim = ClusterSim(cfg, COST)
    s = sim.run(iter(arrivals))
    trace = [(r.user_id, r.hit, r.e2e_ms, r.queue_ms, r.pre_ms,
              r.load_ms, r.rank_ms) for r in sim.records]
    return trace, s


def test_single_tenant_is_trace_identical():
    """Bit-identity contract of the multi-tenant PR (same discipline as
    hosts=1 / page_tokens=0 / segments=off): tenants=1 — the default —
    builds no tenant machinery, and tenant annotations on the stream
    are inert.  Both variants must match the baseline trace and summary
    bit-for-bit over the full parity workload (every HitKind)."""
    base, s0 = _tenant_trace(1, False)
    annotated, s1 = _tenant_trace(1, True)
    assert annotated == base
    assert s1 == s0
