"""Unit + property tests for the RelayGR core (trigger, router, cache,
expander) — the paper's invariants I1/I2 as executable properties."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (AffinityRouter, ConsistentHashRing, DRAMExpander,
                        ExpanderConfig, GRCostModel, HBMCacheStore,
                        SequenceAwareTrigger, SingleFlight, TriggerConfig)
from repro.core.types import HASH_KEY, Request, UserMeta
from repro.models import get_config

COST = GRCostModel(get_config("hstu_gr"))


# ---------------------------------------------------------------------------
# Sequence-aware trigger (Eqs. 1-3)
# ---------------------------------------------------------------------------


def test_trigger_derived_caps_match_paper_example():
    """Paper §3.2 sanity check: Qm=30, M=5, kv_p99=0.1GB, HBM=32GB,
    r1=0.5 -> L<=160, Q_admit<=150; r2=0.1, N=100 -> Qmax<=1500."""
    cfg = TriggerConfig(hbm_bytes=32e9, r1=0.5, q_m=30, m_slots=5,
                        r2=0.1, n_instances=100, t_life_s=160 / 150)
    trig = SequenceAwareTrigger(cfg, COST)
    trig.kv_p99_bytes = 0.1e9  # exact paper constant
    live = cfg.r1 * cfg.hbm_bytes / trig.kv_p99_bytes
    assert live == pytest.approx(160)
    assert trig.q_admit <= 150 + 1e-9
    assert trig.summary()["q_max_pool"] == pytest.approx(1500)


def test_short_sequences_never_admitted():
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    d = trig.admit(UserMeta(user_id=1, prefix_len=64), "i0", 0.0)
    assert not d.admitted and not d.at_risk


def test_long_sequences_at_risk():
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    d = trig.assess(UserMeta(user_id=1, prefix_len=8192))
    assert d.at_risk


@given(qps=st.floats(10, 2000), dur=st.floats(0.5, 5.0))
def test_admission_rate_bounded(qps, dur):
    """Eq. 1/3: admitted rate per instance never exceeds q_admit."""
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    n = int(qps * dur)
    admitted = 0
    for i in range(n):
        t = i / qps
        d = trig.admit(UserMeta(user_id=i, prefix_len=8192), "inst-0", t)
        admitted += d.admitted
    cap = trig.q_admit * dur + trig.q_admit  # rate + initial burst
    assert admitted <= cap + 1


@given(st.integers(256, 32768))
def test_risk_monotone_in_length(n):
    """Longer prefixes are never less at-risk."""
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    a = trig.assess(UserMeta(user_id=1, prefix_len=n))
    b = trig.assess(UserMeta(user_id=1, prefix_len=n + 512))
    assert b.est_full_ms >= a.est_full_ms


# ---------------------------------------------------------------------------
# HBM sliding-window cache (I2)
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(0, 50), st.integers(1, 40)),
                min_size=1, max_size=200))
def test_hbm_budget_never_exceeded(ops):
    store = HBMCacheStore(budget_bytes=100)
    for i, (uid, nbytes) in enumerate(ops):
        store.insert(uid, "psi", nbytes, now=float(i))
        assert store.used_bytes <= 100
    assert store.stats["peak_bytes"] <= 100


def test_hbm_fifo_window_semantics():
    store = HBMCacheStore(budget_bytes=3)
    store.insert(1, "a", 1, 0.0)
    store.insert(2, "b", 1, 1.0)
    store.insert(3, "c", 1, 2.0)
    evicted = store.insert(4, "d", 1, 3.0)
    assert [e.user_id for e in evicted] == [1]      # oldest out
    assert 2 in store and 4 in store and 1 not in store


def test_consumed_flag_tracks():
    store = HBMCacheStore(budget_bytes=10)
    store.insert(1, "a", 5, 0.0)
    assert store.consume(1).consumed
    evicted = store.insert(2, "b", 6, 1.0)
    assert evicted[0].consumed  # consumed-then-evicted -> spill candidate
    assert store.stats["premature_evictions"] == 0


# ---------------------------------------------------------------------------
# Affinity router (I1)
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 10**9), min_size=1, max_size=100))
def test_affinity_producer_consumer_rendezvous(uids):
    """The core contract: pre-infer signal and ranking request with the
    same consistency-hash-key land on the same instance."""
    router = AffinityRouter([f"s{i}" for i in range(7)], ["n0"])
    for uid in uids:
        meta = UserMeta(user_id=uid, prefix_len=4096)
        pre = Request.pre_infer(0, meta)
        rank = Request.rank(1, meta, long_sequence=True)
        assert router.route(pre) == router.route(rank)


@given(st.integers(2, 16), st.integers(200, 1000))
def test_ring_balance(n_nodes, n_keys):
    ring = ConsistentHashRing([f"s{i}" for i in range(n_nodes)], vnodes=256)
    counts = {}
    for k in range(n_keys):
        counts[ring.route(k)] = counts.get(ring.route(k), 0) + 1
    # no instance gets more than 4x the fair share (vnode smoothing)
    assert max(counts.values()) <= 4 * n_keys / n_nodes + 8


@given(st.integers(3, 12))
def test_churn_minimal_remap(n_nodes):
    """Removing one node only remaps keys owned by that node."""
    nodes = [f"s{i}" for i in range(n_nodes)]
    ring = ConsistentHashRing(nodes)
    before = {k: ring.route(k) for k in range(500)}
    ring.remove(nodes[0])
    for k, owner in before.items():
        if owner != nodes[0]:
            assert ring.route(k) == owner


def test_same_user_key_always_same_special_instance():
    """Consistent-hash stability: the binding is a pure function of the
    key and the node set — stable across repeated routes and across
    independently constructed rings."""
    specials = [f"s{i}" for i in range(7)]
    r1 = AffinityRouter(specials, ["n0"])
    r2 = AffinityRouter(list(specials), ["n0", "n1"])  # different normals
    for uid in (0, 1, 42, 12345, 10**8, 987654321):
        req = Request.pre_infer(0, UserMeta(user_id=uid, prefix_len=4096))
        first = r1.route(req)
        for _ in range(25):
            assert r1.route(req) == first
        assert r2.route(req) == first   # normal pool never perturbs it


def test_ring_add_remaps_only_expected_fraction():
    """Adding one instance to an N-node ring moves ~1/(N+1) of the keys
    (vnode smoothing, 3x bound) and every moved key lands on the new
    node; removing it restores the exact prior mapping."""
    nodes = [f"s{i}" for i in range(5)]
    ring = ConsistentHashRing(nodes, vnodes=256)
    keys = range(2000)
    before = {k: ring.route(k) for k in keys}
    ring.add("s5")
    after = {k: ring.route(k) for k in keys}
    moved = [k for k in keys if after[k] != before[k]]
    assert 0 < len(moved) <= 3 * len(before) / (len(nodes) + 1)
    assert all(after[k] == "s5" for k in moved)
    ring.remove("s5")
    assert all(ring.route(k) == before[k] for k in keys)


def test_ring_remove_remaps_only_owned_keys_and_spreads_them():
    """Removing one node orphans only its keys, and the orphans spread
    over the survivors instead of piling onto one neighbour."""
    nodes = [f"s{i}" for i in range(6)]
    ring = ConsistentHashRing(nodes, vnodes=256)
    keys = range(2000)
    before = {k: ring.route(k) for k in keys}
    ring.remove(nodes[2])
    orphan_owners = {ring.route(k) for k, o in before.items()
                     if o == nodes[2]}
    assert len(orphan_owners) >= 3          # vnodes scatter the orphans
    for k, owner in before.items():
        if owner != nodes[2]:
            assert ring.route(k) == owner


def test_normal_traffic_uses_lb_policies():
    router = AffinityRouter(["s0"], ["n0", "n1", "n2"],
                            policy="round_robin")
    meta = UserMeta(user_id=5, prefix_len=10)
    seen = {router.route(Request.rank(i, meta, long_sequence=False))
            for i in range(6)}
    assert seen == {"n0", "n1", "n2"}


# ---------------------------------------------------------------------------
# Memory-aware expander (single flight + pseudo-pre-infer)
# ---------------------------------------------------------------------------


def _entry(uid, nbytes=10):
    from repro.core.cache import CacheEntry
    return CacheEntry(uid, "psi", nbytes, 0.0, prefix_len=2048)


def test_single_flight_leader_follower():
    sf = SingleFlight()
    assert sf.begin(7)          # leader
    assert not sf.begin(7)      # follower
    assert sf.waiters(7) == 1
    sf.end(7)
    sf.end(7)
    assert sf.begin(7)          # fresh burst -> leader again


def test_pseudo_pre_infer_at_most_one_reload():
    """Out-of-order burst: N concurrent ranking requests for one user
    with psi in DRAM -> exactly one reload action."""
    hbm = HBMCacheStore(budget_bytes=10**9)
    exp = DRAMExpander(ExpanderConfig())
    exp.spill(_entry(42))
    actions = [exp.pseudo_pre_infer(42, hbm, 0.0)[0] for _ in range(8)]
    assert actions.count("reload") == 1
    assert actions.count("wait") == 7
    exp.complete_reload(42, hbm, 0.0)
    assert 42 in hbm
    assert exp.stats["reloads"] == 1
    assert exp.stats["redundant_avoided"] == 7


def test_pseudo_pre_infer_hbm_short_circuit():
    hbm = HBMCacheStore(budget_bytes=10**9)
    exp = DRAMExpander(ExpanderConfig())
    hbm.insert(42, "psi", 10, 0.0)
    action, e = exp.pseudo_pre_infer(42, hbm, 0.0)
    assert action == "hbm" and e is not None
    assert exp.stats["reloads"] == 0


@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 30)),
                min_size=1, max_size=100))
def test_dram_budget_never_exceeded(ops):
    exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=100))
    for uid, nbytes in ops:
        exp.spill(_entry(uid, nbytes))
        assert exp.used_bytes <= 100


def test_reload_rate_limited():
    hbm = HBMCacheStore(budget_bytes=10**9)
    exp = DRAMExpander(ExpanderConfig(max_reload_concurrency=0))
    exp.spill(_entry(1))
    action, _ = exp.pseudo_pre_infer(1, hbm, 0.0)
    assert action == "miss"      # throttled -> safe fallback, not a stall
    assert exp.stats["reload_throttled"] == 1


def test_slack_aware_admission():
    """Beyond-paper knob: pre-inference that cannot finish inside the
    retrieval slack is not admitted (ranking would just park on it)."""
    cfg = TriggerConfig(slack_budget_ms=30.0)
    trig = SequenceAwareTrigger(cfg, COST)
    short = UserMeta(user_id=1, prefix_len=2048)   # pre ~26ms fits
    long = UserMeta(user_id=2, prefix_len=16384)   # pre >> 30ms
    assert trig.admit(short, "i", 0.0).admitted
    d = trig.admit(long, "i", 0.0)
    assert not d.admitted and d.reason == "insufficient-slack"
    assert trig.stats["slack_rejected"] == 1


# ---------------------------------------------------------------------------
# admission / cache-tier bugfix sweep regressions
# ---------------------------------------------------------------------------


AT_RISK = dict(prefix_len=8192)   # well past the default rank budget


def test_instance_rate_limit_never_burns_pool_token():
    """Regression: the pool bucket used to be debited BEFORE the
    instance bucket was consulted, so hammering one saturated instance
    silently drained pool-wide admission capacity.  With q_admit=1/inst
    and a pool of 4, rejections on i0 must leave the other three
    instances' admissions intact."""
    cfg = TriggerConfig(q_m=1.0, m_slots=1, r2=1.0, n_instances=4)
    trig = SequenceAwareTrigger(cfg, COST)
    assert trig.q_admit == pytest.approx(1.0)
    assert trig.q_max == pytest.approx(4.0)
    got = [trig.admit(UserMeta(user_id=i, **AT_RISK), "i0", 0.0).admitted
           for i in range(5)]
    assert got == [True, False, False, False, False]
    assert trig.stats["rate_limited_instance"] == 4
    assert trig.stats["rate_limited_pool"] == 0
    # the four instance-level rejections burned NO pool tokens: every
    # other instance still admits from its own burst
    for inst in ("i1", "i2", "i3"):
        d = trig.admit(UserMeta(user_id=hash(inst), **AT_RISK), inst, 0.0)
        assert d.admitted, f"{inst} starved by i0's rejections"
    assert trig.stats["admitted"] == 4
    assert trig.stats["rate_limited"] == 4


def test_pool_rejection_refunds_instance_token():
    """The symmetric leak: a pool-level rejection must hand the already
    taken instance token back, or per-instance capacity erodes under
    pool-wide contention."""
    cfg = TriggerConfig(q_m=2.0, m_slots=1, r2=0.01, n_instances=100)
    trig = SequenceAwareTrigger(cfg, COST)
    assert trig.q_max == pytest.approx(2.0)   # n_special == 1
    assert trig.admit(UserMeta(user_id=1, **AT_RISK), "a", 0.0).admitted
    assert trig.admit(UserMeta(user_id=2, **AT_RISK), "b", 0.0).admitted
    d = trig.admit(UserMeta(user_id=3, **AT_RISK), "a", 0.0)
    assert not d.admitted and d.reason == "pool-rate-limited"
    assert trig.stats["rate_limited_pool"] == 1
    assert trig._instance_buckets["a"].tokens == pytest.approx(1.0), \
        "pool rejection must refund the instance token"


def test_token_bucket_idle_never_accumulates_past_burst():
    """Regression: tokens must not bank past ``burst`` over a long
    idle gap — a year of silence buys one burst, not rate x elapsed."""
    from repro.core.trigger import TokenBucket
    b = TokenBucket(rate=10.0, burst=2.0)
    assert b.try_take(0.0) and b.try_take(0.0)
    assert not b.try_take(0.0)            # burst spent
    year = 3.15e7
    assert b.try_take(year)               # refilled...
    assert b.tokens == pytest.approx(1.0), \
        "idle refill overshot the burst cap"
    assert b.try_take(year)
    assert not b.try_take(year)           # ...to exactly burst, no more


def test_token_bucket_first_take_grants_no_epoch_skew_burst():
    """Regression: the bucket's clock starts at the FIRST take — the
    old ``t_last = 0.0`` init credited the whole wall-clock epoch as
    idle refill, silently topping any below-burst initial allowance up
    to a full free burst on first consult."""
    from repro.core.trigger import TokenBucket
    b = TokenBucket(rate=100.0, burst=50.0, tokens=1.0)
    assert b.try_take(1e9)                # spends the single token
    assert not b.try_take(1e9), \
        "clock-epoch skew minted a free burst on the first take"
    # refill accrues only from the first-take epoch onward
    assert b.try_take(1e9 + 0.0100001)    # 10ms x 100/s = 1 token
    # and the initial allowance itself is capped at burst
    assert TokenBucket(rate=1.0, burst=2.0, tokens=99.0).tokens \
        == pytest.approx(2.0)


def test_token_bucket_out_of_order_timestamp_is_inert():
    """Clamped elapsed time: a timestamp from the past neither mints
    nor drains tokens, and never rewinds the epoch."""
    from repro.core.trigger import TokenBucket
    b = TokenBucket(rate=1.0, burst=1.0)
    assert b.try_take(100.0)
    assert not b.try_take(50.0)           # back in time: no refill
    assert b.tokens == pytest.approx(0.0)
    assert b.try_take(101.0)              # 1s after the TRUE epoch


def test_tenant_rate_limit_preserves_cotenant_share():
    """Multi-tenant admission: a surging tenant exhausts ITS OWN
    bucket (an equal share of the pool rate) and is rejected with
    ``tenant-rate-limited`` — the co-tenant's share stays intact and
    no pool token is burned on the rejection."""
    cfg = TriggerConfig(q_m=2.0, m_slots=1, r2=1.0, n_instances=4,
                        tenants=2)
    trig = SequenceAwareTrigger(cfg, COST)
    assert trig.q_max == pytest.approx(8.0)
    # tenant 0 hammers the pool round-robin: its share is q_max/2 = 4
    got = [trig.admit(UserMeta(user_id=i, tenant=0, **AT_RISK),
                      f"i{i % 4}", 0.0).admitted for i in range(8)]
    assert sum(got) == 4
    d = trig.admit(UserMeta(user_id=99, tenant=0, **AT_RISK), "i3", 0.0)
    assert not d.admitted and d.reason == "tenant-rate-limited"
    assert trig.tenant_stats[0]["rate_limited_tenant"] == 5
    assert trig.stats["rate_limited_tenant"] == 5
    # tenant 1's share is untouched by tenant 0's surge
    d = trig.admit(UserMeta(user_id=100, tenant=1, **AT_RISK), "i3", 0.0)
    assert d.admitted
    assert trig.tenant_stats[1]["admitted"] == 1
    assert trig.tenant_stats[1]["rate_limited"] == 0


def test_tenant_slo_classes_drive_risk():
    """Per-tenant SLO classes: each tenant is at-risk against ITS OWN
    rank budget, so the same prefix can be at-risk for a strict tenant
    and safe for a lenient one."""
    cfg = TriggerConfig(tenants=2,
                        tenant_slo=((0.001, 1e9), (1e9, 1e9)))
    trig = SequenceAwareTrigger(cfg, COST)
    assert trig.assess(UserMeta(user_id=1, tenant=0,
                                prefix_len=2048)).at_risk
    assert not trig.assess(UserMeta(user_id=2, tenant=1,
                                    prefix_len=2048)).at_risk
    assert trig.tenant_stats[0]["at_risk"] == 1
    assert trig.tenant_stats[1]["at_risk"] == 0


def test_single_tenant_builds_no_tenant_machinery():
    """Bit-identity precondition: tenants=1 (default) allocates no
    tenant buckets and no per-tenant ledgers."""
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    assert trig._tenant_buckets == {} and trig.tenant_stats == {}
    d = trig.admit(UserMeta(user_id=1, **AT_RISK), "i", 0.0)
    assert d.admitted and trig.stats["rate_limited_tenant"] == 0


def test_oversized_spill_rejected_up_front():
    """Deterministic core of the property below (runs even where
    hypothesis is unavailable)."""
    exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=100))
    for uid in range(3):
        assert exp.spill(_entry(uid, 30))
    assert not exp.spill(_entry(99, 101))
    assert list(exp.entries) == [0, 1, 2], "doomed spill disturbed the tier"
    assert exp.stats["lru_evictions"] == 0
    assert exp.stats["rejected_spills"] == 1


@given(st.lists(st.tuples(st.integers(0, 20), st.integers(1, 30)),
                min_size=1, max_size=40),
       st.integers(101, 10 ** 6))
def test_oversized_spill_never_drains_tier(ops, big):
    """Regression (mirror of the HBM rejected_inserts fix): a spill
    that can NEVER fit the DRAM budget must be rejected up front — the
    old path LRU-evicted every resident psi before the final fit check
    bounced the entry anyway."""
    exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=100))
    for uid, nbytes in ops:
        exp.spill(_entry(uid, nbytes))
    resident = list(exp.entries)
    used, evictions = exp.used_bytes, exp.stats["lru_evictions"]
    assert not exp.spill(_entry(999, big))
    assert list(exp.entries) == resident, "doomed spill disturbed the tier"
    assert exp.used_bytes == used
    assert exp.stats["lru_evictions"] == evictions
    assert exp.stats["rejected_spills"] == 1


def test_admit_all_reports_real_risk():
    """Regression: the admit-all ablation used to hard-code
    at_risk=True, silently turning every short-sequence request into
    keyed special-pool traffic — the ablation floods ADMISSION only."""
    from repro.core.policies import AdmitAllTrigger
    trig = AdmitAllTrigger(TriggerConfig(), COST)
    d = trig.admit(UserMeta(user_id=1, prefix_len=64), "i", 0.0)
    assert d.admitted and not d.at_risk
    d = trig.admit(UserMeta(user_id=2, **AT_RISK), "i", 0.0)
    assert d.admitted and d.at_risk


def test_segment_value_score_counts_interior_segments():
    """Beyond-prefix reuse: with the segments flag on, admission prices
    the TOTAL reusable tokens (prefix + candidate-independent interior
    segments), not just the prefix."""
    trig = SequenceAwareTrigger(TriggerConfig(), COST)
    meta = UserMeta(user_id=1, prefix_len=2048, incr_len=64,
                    seg_lens=(24, 16))
    assert trig.reusable_tokens(meta) == 2048   # disabled: prefix only
    trig.segments = True
    assert trig.reusable_tokens(meta) == 2048 + 40
    assert trig.admit(meta, "i", 0.0).admitted
    assert trig.stats["reusable_tokens_admitted"] == 2088
