"""Property tests for the HBM sliding-window store (invariant I2).

Hypothesis-driven (via the tests/_hyp.py shim — they skip cleanly on
images without the wheel) over arbitrary insert/consume/pop/lookup
interleavings:

  * ``used_bytes`` never exceeds the budget and always equals the sum of
    live entry sizes;
  * ``peak_bytes`` is monotone non-decreasing;
  * eviction accounting is conserved:
    ``inserts == live_count + evictions`` after ANY interleaving
    (budget-pressure evictions, same-user refreshes and explicit pops
    all leave through the same turnstile);
  * ``premature_evictions`` counts exactly the unconsumed
    budget-pressure victims, and stays zero under a correctly sized
    sequence-aware trigger driving the full relay.
"""

import numpy as np
from _hyp import given, settings, st

from repro.core import ClusterConfig, GRCostModel, TriggerConfig, \
    UserMeta, relay_config
from repro.core.cache import HBMCacheStore, kv_nbytes
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))

OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "consume", "pop", "lookup"]),
              st.integers(0, 7), st.integers(1, 40)),
    max_size=80)


def _drive(store: HBMCacheStore, ops, check=None):
    """Apply an op sequence, running ``check`` after every step."""
    for t, (op, uid, nbytes) in enumerate(ops):
        if op == "insert":
            store.insert(uid, "psi", nbytes, float(t), prefix_len=uid)
        elif op == "consume":
            store.consume(uid)
        elif op == "pop":
            store.pop(uid)
        else:
            store.lookup(uid)
        if check is not None:
            check(store)
    return store


def _invariants(prev_peak):
    def check(store):
        assert 0 <= store.used_bytes <= store.budget
        assert store.used_bytes == sum(
            e.nbytes for e in store.entries.values())
        assert store.stats["peak_bytes"] >= prev_peak[0]
        prev_peak[0] = store.stats["peak_bytes"]
        assert store.stats["inserts"] == \
            store.live_count + store.stats["evictions"]
        assert store.stats["premature_evictions"] <= store.stats["evictions"]
    return check


@given(OPS, st.integers(20, 120))
@settings(max_examples=60, deadline=None)
def test_budget_peak_and_conservation_under_any_interleaving(ops, budget):
    _drive(HBMCacheStore(budget), ops, _invariants([0]))


@given(OPS)
@settings(max_examples=30, deadline=None)
def test_oversized_inserts_never_land(ops):
    """An entry larger than the whole budget must clear the window but
    never enter it (and never count as an insert)."""
    store = _drive(HBMCacheStore(25), ops)
    evicted = store.insert(99, "psi", 26, 1e9)
    assert 99 not in store
    assert store.live_count == 0 and store.used_bytes == 0
    assert all(e.user_id != 99 for e in evicted)
    assert store.stats["inserts"] == store.stats["evictions"]


def test_conservation_example_paths():
    """Pin the three exit turnstiles without hypothesis: budget
    eviction, same-user refresh, explicit pop."""
    store = HBMCacheStore(10)
    store.insert(1, "a", 6, 0.0)
    store.insert(1, "a2", 6, 1.0)          # refresh: 1 eviction
    assert store.stats["evictions"] == 1
    assert store.stats["premature_evictions"] == 0
    store.insert(2, "b", 6, 2.0)           # pressure: evicts unconsumed 1
    assert store.stats["evictions"] == 2
    assert store.stats["premature_evictions"] == 1
    store.consume(2)
    store.pop(2)                           # explicit exit, not premature
    assert store.stats["evictions"] == 3
    assert store.stats["premature_evictions"] == 1
    assert store.stats["inserts"] == 3 == \
        store.live_count + store.stats["evictions"]
    assert store.used_bytes == 0


def test_kv_nbytes_sizes_pytrees():
    kv = (np.zeros((2, 1, 64, 2, 32), np.float32),
          np.zeros((2, 1, 64, 2, 32), np.float32))
    assert kv_nbytes(kv) == 2 * 2 * 64 * 2 * 32 * 4
    assert kv_nbytes({"k": kv, "v": [kv]}) == 2 * kv_nbytes(kv)
    assert kv_nbytes(("psi", 7, 2048)) == 0   # sim executor stub


@given(st.integers(1500, 3500), st.integers(0, 3))
@settings(max_examples=6, deadline=None)
def test_premature_evictions_zero_under_sequence_aware_trigger(L, seed):
    """I2 end-to-end: a *correctly sized* sequence-aware trigger —
    kv_p99_len covering the workload, hbm_bytes matching the store
    budget, q_m derived from the actual pre-infer cost, and slack-aware
    admission so psi always lands before its ranking — never lets an
    admitted cache die unconsumed, for any sequence length in the
    admitting regime and any arrival seed."""
    hbm = 2e9
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5,
                              kv_p99_len=max(L, 4096),
                              hbm_bytes=hbm / 0.5, r1=0.5,
                              q_m=1e3 / COST.pre_infer_ms(L),
                              slack_budget_ms=65.0),
        cluster=ClusterConfig(hbm_cache_bytes=hbm, dram_budget_bytes=0.0))
    rng = np.random.default_rng(seed)
    t, arr = 0.0, []
    for _ in range(200):
        t += rng.exponential(1.0 / 80.0)
        arr.append((t, UserMeta(user_id=int(rng.integers(0, 10 ** 9)),
                                prefix_len=L)))
    sim = ClusterSim(cfg, COST)
    sim.run(iter(arr))
    assert any(i.hbm.stats["inserts"] > 0
               for i in sim.instances.values()), "vacuous: nothing admitted"
    for inst in sim.instances.values():
        assert inst.hbm.stats["premature_evictions"] == 0
        assert inst.hbm.stats["inserts"] == \
            inst.hbm.live_count + inst.hbm.stats["evictions"]
