"""Property tests for the HBM sliding-window store (invariant I2).

Hypothesis-driven (via the tests/_hyp.py shim — they skip cleanly on
images without the wheel) over arbitrary insert/consume/pop/lookup
interleavings:

  * ``used_bytes`` never exceeds the budget and always equals the sum of
    live entry sizes;
  * ``peak_bytes`` is monotone non-decreasing;
  * eviction accounting is conserved:
    ``inserts == live_count + evictions`` after ANY interleaving
    (budget-pressure evictions, same-user refreshes and explicit pops
    all leave through the same turnstile);
  * ``premature_evictions`` counts exactly the unconsumed
    budget-pressure victims, and stays zero under a correctly sized
    sequence-aware trigger driving the full relay.

Paged-store extensions (``PagedHBMStore`` / ``PagePool``):

  * page conservation — ``pages_allocated == pages_live + pages_freed``
    after any interleaving, pins/zombies included;
  * the free list never double-allocates a page;
  * occupancy under mixed prefix lengths beats the unpaged store at the
    same byte budget (fragmentation is bounded by last-page padding);
  * ``premature_evictions == 0`` end-to-end under a correctly sized
    trigger with the paged window.
"""

import numpy as np
from _hyp import given, settings, st

from repro.core import ClusterConfig, GRCostModel, PageLayout, \
    TriggerConfig, UserMeta, relay_config
from repro.core.cache import HBMCacheStore, PagedHBMStore, kv_nbytes
from repro.core.paging import PagePool
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))

OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "consume", "pop", "lookup"]),
              st.integers(0, 7), st.integers(1, 40)),
    max_size=80)


def _drive(store: HBMCacheStore, ops, check=None):
    """Apply an op sequence, running ``check`` after every step."""
    for t, (op, uid, nbytes) in enumerate(ops):
        if op == "insert":
            store.insert(uid, "psi", nbytes, float(t), prefix_len=uid)
        elif op == "consume":
            store.consume(uid)
        elif op == "pop":
            store.pop(uid)
        else:
            store.lookup(uid)
        if check is not None:
            check(store)
    return store


def _invariants(prev_peak):
    def check(store):
        assert 0 <= store.used_bytes <= store.budget
        assert store.used_bytes == sum(
            e.nbytes for e in store.entries.values())
        assert store.stats["peak_bytes"] >= prev_peak[0]
        prev_peak[0] = store.stats["peak_bytes"]
        assert store.stats["inserts"] == \
            store.live_count + store.stats["evictions"]
        assert store.stats["premature_evictions"] <= store.stats["evictions"]
    return check


@given(OPS, st.integers(20, 120))
@settings(max_examples=60, deadline=None)
def test_budget_peak_and_conservation_under_any_interleaving(ops, budget):
    _drive(HBMCacheStore(budget), ops, _invariants([0]))


@given(OPS)
@settings(max_examples=30, deadline=None)
def test_oversized_inserts_rejected_without_disturbing_window(ops):
    """An entry larger than the whole budget never enters the window —
    and, since the fix, never clears it either: the insert is rejected
    up front, counted in ``rejected_inserts``, and the resident entries
    are left alone (no manufactured premature evictions)."""
    store = _drive(HBMCacheStore(25), ops)
    live_before = store.live_count
    used_before = store.used_bytes
    evicted = store.insert(99, "psi", 26, 1e9)
    assert 99 not in store
    assert evicted == []
    assert store.live_count == live_before
    assert store.used_bytes == used_before
    assert store.stats["rejected_inserts"] >= 1
    assert store.stats["inserts"] == \
        store.live_count + store.stats["evictions"]


def test_conservation_example_paths():
    """Pin the three exit turnstiles without hypothesis: budget
    eviction, same-user refresh, explicit pop."""
    store = HBMCacheStore(10)
    store.insert(1, "a", 6, 0.0)
    store.insert(1, "a2", 6, 1.0)          # refresh: 1 eviction
    assert store.stats["evictions"] == 1
    assert store.stats["premature_evictions"] == 0
    store.insert(2, "b", 6, 2.0)           # pressure: evicts unconsumed 1
    assert store.stats["evictions"] == 2
    assert store.stats["premature_evictions"] == 1
    store.consume(2)
    store.pop(2)                           # explicit exit, not premature
    assert store.stats["evictions"] == 3
    assert store.stats["premature_evictions"] == 1
    assert store.stats["inserts"] == 3 == \
        store.live_count + store.stats["evictions"]
    assert store.used_bytes == 0


def test_kv_nbytes_sizes_pytrees():
    kv = (np.zeros((2, 1, 64, 2, 32), np.float32),
          np.zeros((2, 1, 64, 2, 32), np.float32))
    assert kv_nbytes(kv) == 2 * 2 * 64 * 2 * 32 * 4
    assert kv_nbytes({"k": kv, "v": [kv]}) == 2 * kv_nbytes(kv)
    assert kv_nbytes(("psi", 7, 2048)) == 0   # sim executor stub


# ---------------------------------------------------------------------------
# paged store (PagedHBMStore / PagePool)
# ---------------------------------------------------------------------------

# small geometry so hypothesis explores pressure quickly: 4 slabs
# (2 layers x K/V), 8-token pages, 1 byte per token per slab
LAYOUT = PageLayout(page_tokens=8, slabs=4, token_bytes=1)


def _paged_store(pool_pages: int) -> PagedHBMStore:
    return PagedHBMStore(pool_pages * LAYOUT.page_bytes, LAYOUT)


def _paged_invariants(store: PagedHBMStore):
    pool = store.pool
    # page conservation: every page ever allocated is live or freed
    assert pool.stats["pages_allocated"] == \
        pool.pages_live + pool.stats["pages_freed"]
    # entry bytes are whole pages and sum to used_bytes
    assert store.used_bytes == sum(e.nbytes for e in store.entries.values())
    assert all(e.nbytes % LAYOUT.page_bytes == 0
               for e in store.entries.values())
    # entry accounting stays conserved under paging
    assert store.stats["inserts"] == \
        store.live_count + store.stats["evictions"]
    # live tables reference live pages only, with no page shared
    seen = set()
    for e in store.entries.values():
        pps = LAYOUT.pages_per_slab(e.tokens_resident) \
            if e.tokens_resident else 0
        for p in e.page_table[:, :pps].reshape(-1):
            assert int(p) not in seen, "page double-allocated"
            seen.add(int(p))


PAGED_OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "consume", "pop", "lookup"]),
              st.integers(0, 7), st.integers(1, 80)),
    max_size=80)


@given(PAGED_OPS, st.integers(6, 40))
@settings(max_examples=60, deadline=None)
def test_paged_conservation_under_any_interleaving(ops, pool_pages):
    store = _paged_store(pool_pages)
    for t, (op, uid, tokens) in enumerate(ops):
        if op == "insert":
            store.insert(uid, "psi", LAYOUT.entry_bytes(tokens), float(t),
                         prefix_len=tokens)
        elif op == "consume":
            store.consume(uid)
        elif op == "pop":
            store.pop(uid)
        else:
            store.lookup(uid)
        _paged_invariants(store)


@given(st.lists(st.tuples(st.integers(1, 6), st.booleans()), max_size=60),
       st.integers(4, 24))
@settings(max_examples=60, deadline=None)
def test_free_list_never_double_allocates(plan, pool_pages):
    """Drive alloc/free (with pins interleaved) directly on the pool:
    outstanding allocations never overlap and conservation holds."""
    pool = PagePool(pool_pages, page_bytes=8)
    outstanding = []
    for n, pin in plan:
        pages = pool.alloc(n)
        if pages is not None:
            assert len(set(pages)) == len(pages)
            flat = {p for ps in outstanding for p in ps}
            assert not flat & set(pages), "double allocation"
            if pin:
                pool.pin(pages)
            outstanding.append((pages, pin))
        elif outstanding:
            pages_, pinned = outstanding.pop(0)
            pool.free(pages_)
            if pinned:
                # zombie until unpinned: still counted live
                assert pool.stats["pages_allocated"] == \
                    pool.pages_live + pool.stats["pages_freed"]
                pool.unpin(pages_)
        assert pool.stats["pages_allocated"] == \
            pool.pages_live + pool.stats["pages_freed"]
        assert 0 <= pool.free_pages <= pool.n_pages


@given(st.lists(st.integers(1, 100), min_size=4, max_size=30),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_paged_occupancy_beats_dense_under_mixed_lengths(lens, seed):
    """The headline fragmentation claim: with mixed prefix lengths under
    one byte budget, the paged window keeps AT LEAST as many psi
    resident as the dense store (its only waste is last-page padding,
    the dense store fragments on whole-entry granularity)."""
    budget = 40 * LAYOUT.page_bytes
    dense = HBMCacheStore(budget)
    paged = _paged_store(40)
    rng = np.random.default_rng(seed)
    for i, tokens in enumerate(lens):
        uid = int(rng.integers(0, 10 ** 6))
        # the dense store ships the 64-grid padded pytree; charge the
        # paged store its page-rounded footprint for the same psi
        dense.insert(uid, "psi", LAYOUT.slabs * LAYOUT.token_bytes
                     * (-(-tokens // 64) * 64), float(i),
                     prefix_len=tokens)
        paged.insert(uid, "psi", LAYOUT.entry_bytes(tokens), float(i),
                     prefix_len=tokens)
    assert paged.live_count >= dense.live_count
    _paged_invariants(paged)


def test_paged_partial_eviction_and_resume_pinned_example():
    """Pin the partial-eviction -> resumed-reload path without
    hypothesis: tail pages of the oldest consumed DRAM-backed entry go
    first, the head stays resident, and the resume streams only the
    missing tokens."""
    store = _paged_store(10 * LAYOUT.slabs)   # 10 pages per slab
    e8 = LAYOUT.entry_bytes(8 * LAYOUT.page_tokens)
    store.insert(1, "psi", e8, 0.0, prefix_len=8 * LAYOUT.page_tokens)
    store.consume(1)
    store.entries[1].dram_backed = True
    store.insert(2, "psi", LAYOUT.entry_bytes(4 * LAYOUT.page_tokens), 1.0,
                 prefix_len=4 * LAYOUT.page_tokens)
    assert store.stats["partial_evictions"] == 1
    assert store.stats["evictions"] == 0
    e = store.entries[1]
    assert 0 < e.tokens_resident < e.prefix_len
    assert store.lookup(1) is None            # partial != servable
    missing = store.missing_tokens(1, e.prefix_len)
    assert missing == e.prefix_len - e.tokens_resident
    store.insert(1, "psi", e8, 2.0, prefix_len=e.prefix_len)
    assert store.stats["resumed_reloads"] == 1
    assert store.entries[1].tokens_resident == e.prefix_len
    assert store.lookup(1) is not None
    _paged_invariants(store)


def test_paged_pinned_pages_survive_eviction():
    """A page pinned by an in-flight launch is freed only after release
    (zombie defer) — and is never handed to a new allocation first."""
    store = _paged_store(2 * LAYOUT.slabs)
    t8 = LAYOUT.page_tokens * 2               # 2 pages per slab
    store.insert(1, "psi", LAYOUT.entry_bytes(t8), 0.0, prefix_len=t8)
    psi = store.acquire_value(store.entries[1])
    pinned = {int(p) for p in store.entries[1].page_table.reshape(-1)}
    store.insert(2, "psi", LAYOUT.entry_bytes(t8), 1.0, prefix_len=t8)
    # user 1 evicted under pressure, but its pages are pinned: user 2's
    # insert must have been rejected rather than reuse them
    assert 1 not in store
    assert store.pool.zombie_pages == len(pinned)
    assert 2 not in store
    assert store.stats["rejected_inserts"] == 1
    store.release_value(psi)
    assert store.pool.zombie_pages == 0
    store.insert(2, "psi", LAYOUT.entry_bytes(t8), 2.0, prefix_len=t8)
    assert 2 in store
    pool = store.pool
    assert pool.stats["pages_allocated"] == \
        pool.pages_live + pool.stats["pages_freed"]


@given(st.integers(1500, 3500), st.integers(0, 3))
@settings(max_examples=4, deadline=None)
def test_premature_evictions_zero_under_trigger_paged(L, seed):
    """The end-to-end I2 guarantee survives paging: a correctly sized
    sequence-aware trigger over the PAGED window never lets an admitted
    cache die unconsumed."""
    hbm = 2e9
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5,
                              kv_p99_len=max(L, 4096),
                              hbm_bytes=hbm / 0.5, r1=0.5,
                              q_m=1e3 / COST.pre_infer_ms(L),
                              slack_budget_ms=65.0),
        cluster=ClusterConfig(hbm_cache_bytes=hbm, dram_budget_bytes=0.0,
                              page_tokens=64))
    rng = np.random.default_rng(seed)
    t, arr = 0.0, []
    for _ in range(200):
        t += rng.exponential(1.0 / 80.0)
        arr.append((t, UserMeta(user_id=int(rng.integers(0, 10 ** 9)),
                                prefix_len=L)))
    sim = ClusterSim(cfg, COST)
    sim.run(iter(arr))
    assert any(i.hbm.stats["inserts"] > 0
               for i in sim.instances.values()), "vacuous: nothing admitted"
    for inst in sim.instances.values():
        assert inst.hbm.stats["premature_evictions"] == 0
        assert inst.hbm.stats["inserts"] == \
            inst.hbm.live_count + inst.hbm.stats["evictions"]
        pool = inst.hbm.pool
        assert pool.stats["pages_allocated"] == \
            pool.pages_live + pool.stats["pages_freed"]


@given(st.integers(1500, 3500), st.integers(0, 3))
@settings(max_examples=6, deadline=None)
def test_premature_evictions_zero_under_sequence_aware_trigger(L, seed):
    """I2 end-to-end: a *correctly sized* sequence-aware trigger —
    kv_p99_len covering the workload, hbm_bytes matching the store
    budget, q_m derived from the actual pre-infer cost, and slack-aware
    admission so psi always lands before its ranking — never lets an
    admitted cache die unconsumed, for any sequence length in the
    admitting regime and any arrival seed."""
    hbm = 2e9
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5,
                              kv_p99_len=max(L, 4096),
                              hbm_bytes=hbm / 0.5, r1=0.5,
                              q_m=1e3 / COST.pre_infer_ms(L),
                              slack_budget_ms=65.0),
        cluster=ClusterConfig(hbm_cache_bytes=hbm, dram_budget_bytes=0.0))
    rng = np.random.default_rng(seed)
    t, arr = 0.0, []
    for _ in range(200):
        t += rng.exponential(1.0 / 80.0)
        arr.append((t, UserMeta(user_id=int(rng.integers(0, 10 ** 9)),
                                prefix_len=L)))
    sim = ClusterSim(cfg, COST)
    sim.run(iter(arr))
    assert any(i.hbm.stats["inserts"] > 0
               for i in sim.instances.values()), "vacuous: nothing admitted"
    for inst in sim.instances.values():
        assert inst.hbm.stats["premature_evictions"] == 0
        assert inst.hbm.stats["inserts"] == \
            inst.hbm.live_count + inst.hbm.stats["evictions"]
