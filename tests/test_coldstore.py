"""Cold-tier correctness: bit-identity through demotion/promotion and
the promotion-vs-rank race.

Three groups:

  * ``ColdStore`` unit properties (hypothesis via the tests/_hyp shim):
    an inserted psi comes back byte-identical through ``take``, and the
    unified counter family conserves
    ``inserts == live + evictions + handoffs + promotions`` under any
    insert/take/extract/drop interleaving.

  * Full-hierarchy round trips: psi leaves a (paged) HBM window, spills
    to the DRAM expander, demotes into the cold store under LRU
    pressure, promotes back out, and re-pages into a fresh window —
    and the ranking-visible bytes are identical at every hop.  Includes
    the multi-span segment case (beyond-prefix reuse entries whose
    spans pad to whole pages) because that is where a sloppy
    materialize/re-page cycle would silently corrupt the layout.

  * Regression: a rank racing its OWN in-flight cold promotion is
    served as a miss immediately (``cold["late_miss"]``) instead of
    stalling on disk I/O, and the promoted copy still lands —
    consumed-on-arrival, serving future requests, never a premature
    eviction.
"""

import numpy as np
from _hyp import given, settings, st

from repro.core import (ClusterConfig, GRCostModel, TriggerConfig,
                        UserMeta, relay_config)
from repro.core.cache import CacheEntry, HBMCacheStore, PagedHBMStore
from repro.core.coldstore import ColdStore, ColdStoreConfig
from repro.core.expander import DRAMExpander, ExpanderConfig
from repro.core.paging import PageLayout, ceil_div
from repro.core.runtime import Record
from repro.core.types import CacheState
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))


def _psi_bytes(value):
    """Canonical byte string of a dense (K, V) psi pytree."""
    k, v = value
    return np.asarray(k).tobytes() + np.asarray(v).tobytes()


def _dense_psi(rng, n_layers, tokens, heads, dim):
    shape = (n_layers, 1, tokens, heads, dim)
    k = rng.standard_normal(shape).astype(np.float32)
    v = rng.standard_normal(shape).astype(np.float32)
    return k, v


def _store_conserved(s: ColdStore):
    st_ = s.stats
    assert st_["inserts"] == (s.live_count + st_["evictions"]
                              + st_["handoffs"] + st_["promotions"]), st_
    assert s.used_bytes == sum(e.nbytes for e in s.entries.values())
    assert s.used_bytes <= s.cfg.budget_bytes


# ---------------------------------------------------------------------------
# unit properties
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["insert", "take", "extract", "drop", "lookup"]),
    st.integers(0, 5), st.integers(1, 40)), max_size=60))
def test_coldstore_conservation(ops):
    """inserts == live + evictions + handoffs + promotions after ANY
    interleaving, and used_bytes tracks the live set exactly."""
    s = ColdStore(ColdStoreConfig(budget_bytes=100))
    for t, (op, uid, nbytes) in enumerate(ops):
        if op == "insert":
            s.insert(CacheEntry(uid, "psi", nbytes, float(t),
                                prefix_len=uid))
        elif op == "take":
            s.take(uid)
        elif op == "extract":
            s.extract(uid)
        elif op == "drop":
            s.drop(uid)
        else:
            s.lookup(uid)
        _store_conserved(s)
    probes = s.stats["hits"] + s.stats["misses"]
    assert probes == sum(1 for op, _, _ in ops if op == "lookup")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(1, 3), st.integers(1, 64),
       st.integers(1, 4), st.integers(1, 8))
def test_coldstore_roundtrip_bitwise(seed, n_layers, tokens, heads, dim):
    """insert -> take returns the psi byte-identical: the cold tier is
    storage, never a transform."""
    rng = np.random.default_rng(seed)
    value = _dense_psi(rng, n_layers, tokens, heads, dim)
    ref = _psi_bytes(value)
    e = CacheEntry(7, value, len(ref), 0.0, prefix_len=tokens,
                   spans=((0, tokens),))
    s = ColdStore(ColdStoreConfig(budget_bytes=len(ref)))
    assert s.insert(e)
    assert s.peek(7).state is CacheState.COLD
    out = s.take(7)
    assert out is not None and _psi_bytes(out.value) == ref
    assert out.spans == ((0, tokens),) and out.prefix_len == tokens
    _store_conserved(s)


def test_coldstore_rejects_unfit_and_replaces_stale():
    s = ColdStore(ColdStoreConfig(budget_bytes=100))
    assert not s.insert(CacheEntry(1, "psi", 101, 0.0))   # can never fit
    assert s.stats["rejected_inserts"] == 1
    assert not s.insert(CacheEntry(2, None, 10, 0.0))     # no payload
    assert s.insert(CacheEntry(3, "old", 60, 0.0))
    assert s.insert(CacheEntry(3, "new", 60, 1.0))        # same-user refresh
    assert s.stats["evictions"] == 1 and s.live_count == 1
    assert s.peek(3).value == "new"
    _store_conserved(s)


# ---------------------------------------------------------------------------
# full-hierarchy round trips
# ---------------------------------------------------------------------------


def _layout(n_layers=2, heads=2, dim=4, page_tokens=16):
    return PageLayout(page_tokens=page_tokens, slabs=2 * n_layers,
                      token_bytes=heads * dim * 4)


def _padded_tokens(spans, page_tokens):
    return sum(page_tokens * ceil_div(int(ln), page_tokens)
               for _, ln in spans)


def _paged_roundtrip(spans, seed=0, n_layers=2, heads=2, dim=4,
                     page_tokens=16):
    """Window -> DRAM -> cold -> DRAM -> fresh window; returns the
    reference bytes and the bytes the final window would rank with."""
    rng = np.random.default_rng(seed)
    lay = _layout(n_layers, heads, dim, page_tokens)
    tokens = _padded_tokens(spans, page_tokens)
    value = _dense_psi(rng, n_layers, tokens, heads, dim)
    hbm = PagedHBMStore(lay.entry_bytes(tokens) * 2, lay)
    assert hbm.insert(11, value, lay.entry_bytes(tokens), 0.0,
                      prefix_len=tokens, spans=spans) == []
    entry = hbm.entries[11]
    ref = _psi_bytes(entry.value.materialize())   # pool-truth reference

    # spill: the expander materializes the paged psi to a dense copy
    exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=entry.nbytes))
    cold = ColdStore(ColdStoreConfig(budget_bytes=10 * entry.nbytes))
    exp.demote_sink = cold.insert
    hbm.consume(11)   # spills happen post-consumption (paged _evict
    assert exp.spill(hbm.pop(11))   # only materializes a served psi)
    d = exp.entries[11]
    assert not isinstance(d.value, PagedHBMStore)
    assert _psi_bytes(d.value) == ref and d.spans == spans

    # LRU pressure demotes it into the cold store...
    filler = CacheEntry(12, _dense_psi(rng, n_layers, tokens, heads, dim),
                        entry.nbytes, 1.0, prefix_len=tokens)
    assert exp.spill(filler)
    assert exp.stats["demotions"] == 1 and cold.stats["inserts"] == 1
    _store_conserved(cold)

    # ...and a promotion brings it back up, byte-identical
    up = cold.take(11)
    assert _psi_bytes(up.value) == ref and up.spans == spans
    exp2 = DRAMExpander(ExpanderConfig(dram_budget_bytes=10 * entry.nbytes))
    assert exp2.spill(up)
    hbm2 = PagedHBMStore(lay.entry_bytes(tokens) * 2, lay)
    exp2.complete_reload(11, hbm2, 2.0)
    back = hbm2.resident(11)
    assert back is not None and back.spans == spans
    return ref, _psi_bytes(back.value.materialize())


def test_roundtrip_prefix_only_paged():
    ref, back = _paged_roundtrip(((0, 48),))
    assert back == ref


def test_roundtrip_multispan_segments():
    """The beyond-prefix case: spans pad to whole pages independently;
    a demotion/promotion cycle must reproduce the padded layout (zero
    tails included) bit-for-bit, or the paged kernel's position tables
    would read garbage."""
    ref, back = _paged_roundtrip(((0, 40), (64, 20), (160, 7)))
    assert back == ref


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31),
       st.lists(st.integers(1, 40), min_size=1, max_size=4),
       st.sampled_from([8, 16]))
def test_roundtrip_multispan_property(seed, lens, page_tokens):
    spans, cursor = [], 0
    for ln in lens:
        spans.append((cursor, ln))
        cursor += 3 * ln
    ref, back = _paged_roundtrip(tuple(spans), seed=seed,
                                 page_tokens=page_tokens)
    assert back == ref


def test_roundtrip_dense_store():
    """Same cycle over the unpaged window: the value object rides the
    hierarchy untouched."""
    rng = np.random.default_rng(3)
    value = _dense_psi(rng, 2, 32, 2, 4)
    ref = _psi_bytes(value)
    hbm = HBMCacheStore(10 ** 6)
    hbm.insert(5, value, len(ref), 0.0, prefix_len=32)
    hbm.consume(5)
    exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=len(ref)))
    cold = ColdStore(ColdStoreConfig(budget_bytes=10 ** 6))
    exp.demote_sink = cold.insert
    assert exp.spill(hbm.pop(5))
    assert exp.spill(CacheEntry(6, "filler", len(ref), 1.0))
    up = cold.take(5)
    assert up is not None and _psi_bytes(up.value) == ref
    up.cold_sourced = True   # the runtime marks revivals (_on_promote_done)
    hbm2 = HBMCacheStore(10 ** 6)
    exp2 = DRAMExpander(ExpanderConfig(dram_budget_bytes=10 ** 6))
    assert exp2.spill(up)
    exp2.complete_reload(5, hbm2, 2.0)
    assert _psi_bytes(hbm2.resident(5).value) == ref
    # the marker rode the whole cycle: the rank this copy unblocks
    # classifies as a cold hit
    assert hbm2.resident(5).cold_sourced


# ---------------------------------------------------------------------------
# promotion-vs-rank race regression
# ---------------------------------------------------------------------------


def _race_runtime():
    trig = TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5,
                         kv_p99_len=4096, hbm_bytes=4e9, r1=0.5,
                         q_m=1e3 / COST.pre_infer_ms(3072))
    cfg = relay_config(trigger=trig, cluster=ClusterConfig(
        hbm_cache_bytes=2e9, dram_budget_bytes=150e6,
        cold_budget_bytes=400e9))
    return ClusterSim(cfg, COST).runtime


def test_rank_racing_own_promotion_served_as_miss():
    """A cold-resident user whose rank request lands while the cold
    read is still in flight must be served as a miss NOW — never parked
    on disk I/O — and the promoted copy still lands for future reuse,
    consumed-on-arrival (its lifecycle already missed)."""
    rt = _race_runtime()
    uid = 424242
    meta = UserMeta(user_id=uid, prefix_len=2048)
    target = rt.router.route_key(uid)
    host = rt.topology.host_of(target)
    store = rt.cold_stores[host]
    assert store.insert(CacheEntry(uid, "psi", COST.kv_bytes(2048), 0.0,
                                   prefix_len=2048))

    # pre signal at t=0 starts the (viable) promotion; the rank arrives
    # 1 ms later — long before the ~5 ms cold read completes
    rec = Record(user_id=uid, t_arrival=0.0, prefix_len=2048,
                 ctx_tokens=2048 + meta.incr_len)
    rt.schedule(0.0, "pre_signal", meta=meta, target=target)
    rt.schedule(0.001, "rank_arrival", meta=meta, rec=rec)
    rt.drain()

    inst = rt.instances[target]
    assert rec.hit == "miss"
    assert rt.cold["late_miss"] == 1 and rt.cold["promotions"] == 1
    # no stall: the raced rank paid neither park time on the in-flight
    # psi nor a reload leg — it fell back to full inference immediately
    assert rec.pre_ms == 0.0 and rec.load_ms == 0.0
    assert rec.rank_ms > 0.0 and rec.t_done > 0.0
    # the promotion still landed: resident, pre-consumed, and no longer
    # marked cold_sourced (the lifecycle it was revived for is over)
    e = inst.hbm.resident(uid)
    assert e is not None and e.consumed and not e.cold_sourced
    assert not rt._promote_raced and not rt._promote_inflight
    assert inst.hbm.stats["premature_evictions"] == 0
    # drained ledger: the store counted exactly one promotion out
    assert store.stats["promotions"] == 1 and store.live_count == 0
    _store_conserved(store)


def test_demote_family_conserved_mid_flight():
    """Regression (demote/evict race): the demote conservation family
    must hold at EVERY event boundary —

        demotions == demote_landed + demote_dropped + demote_inflight

    The old ledger had no inflight term, so any ``stats()`` probe
    inside the write window (the copy has left DRAM but the cold write
    has not completed) transiently violated the family."""
    rt = _race_runtime()
    host = next(iter(rt.cold_stores))
    entry = CacheEntry(111, "psi", COST.kv_bytes(2048), 0.0,
                       prefix_len=2048)
    assert rt._demote(0.0, host, entry)

    def family(c):
        return c["demotions"] == (c["demote_landed"]
                                  + c["demote_dropped"]
                                  + c["demote_inflight"])

    # mid-flight: the write is scheduled but not landed
    assert rt.cold["demote_inflight"] == 1
    assert rt.cold["demote_landed"] == 0
    assert family(rt.cold)
    assert family(rt.stats()["cold"])
    rt.drain()
    # drained: the inflight term resolves to a landing and the
    # pre-inflight end-state invariant still holds exactly
    c = rt.cold
    assert c["demote_inflight"] == 0
    assert c["demotions"] == 1 == c["demote_landed"]
    assert c["demote_dropped"] == 0 and family(c)
    store = rt.cold_stores[host]
    assert store.live_count == 1
    _store_conserved(store)


def test_demote_family_conserved_under_racing_demotes():
    """Deterministic interleaving of the race itself: two demotions of
    the SAME user are in flight together (the second supersedes the
    first — its landing replaces the stale copy, counted as a cold
    eviction).  The family holds at each boundary and after the drain
    the store's own conservation closes over the replacement."""
    rt = _race_runtime()
    host = next(iter(rt.cold_stores))
    for ts in (0.0, 0.0005):
        e = CacheEntry(7, "psi", COST.kv_bytes(1024), ts, prefix_len=1024)
        assert rt._demote(ts, host, e)
        c = rt.cold
        assert c["demotions"] == (c["demote_landed"] + c["demote_dropped"]
                                  + c["demote_inflight"]), c
    assert rt.cold["demote_inflight"] == 2
    rt.drain()
    c = rt.cold
    assert c["demote_inflight"] == 0
    assert c["demotions"] == 2 == c["demote_landed"] + c["demote_dropped"]
    store = rt.cold_stores[host]
    assert store.stats["inserts"] == 2 and store.stats["evictions"] == 1
    assert store.live_count == 1
    _store_conserved(store)


def test_promotion_wins_when_rank_arrives_on_time():
    """Control for the race test: with the full 62 ms pre-signal ->
    rank window the promotion lands first and the rank classifies as a
    cold hit (then the marker clears — later visits are warm hits)."""
    rt = _race_runtime()
    uid = 424243
    meta = UserMeta(user_id=uid, prefix_len=2048)
    target = rt.router.route_key(uid)
    store = rt.cold_stores[rt.topology.host_of(target)]
    assert store.insert(CacheEntry(uid, "psi", COST.kv_bytes(2048), 0.0,
                                   prefix_len=2048))
    summary = rt.run([(0.0, meta)])
    assert summary["cold_hit"] > 0.0
    assert rt.cold["promotions"] == 1 and rt.cold["late_miss"] == 0
    assert rt.records[0].hit == "cold_hit"
    e = rt.instances[target].hbm.resident(uid)
    assert e is not None and not e.cold_sourced
