"""Equivalence tests for the §Perf hillclimb features: none of the
performance changes may alter numerics (beyond fp reassociation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, get_model
from repro.models.arch import ce_loss, _logits
from repro.models.layers import attention, attention_specs, init_tree
from repro.training import optimizer as opt

RNG = np.random.default_rng(11)


def _x(B, S, d):
    return jnp.asarray(RNG.normal(size=(B, S, d)), jnp.float32)


def test_q_chunked_attention_matches_unchunked():
    cfg = get_config("starcoder2_7b", smoke=True)
    params = init_tree(attention_specs(cfg), jax.random.PRNGKey(0))
    x = _x(2, 64, cfg.d_model)
    pos = jnp.arange(64)[None]
    o1, _ = attention(params, x, cfg, positions=pos)
    o2, _ = attention(params, x, dataclasses.replace(cfg, attn_q_chunk=16),
                      positions=pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)


def test_q_chunked_attention_with_window():
    cfg = dataclasses.replace(get_config("starcoder2_7b", smoke=True),
                              sliding_window=32)
    params = init_tree(attention_specs(cfg), jax.random.PRNGKey(0))
    x = _x(2, 64, cfg.d_model)
    pos = jnp.arange(64)[None]
    o1, _ = attention(params, x, cfg, positions=pos, window=32)
    o2, _ = attention(params, x, dataclasses.replace(cfg, attn_q_chunk=16),
                      positions=pos, window=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)


def test_head_padding_exact_semantics():
    """Padded heads contribute nothing and the GQA kv-grouping of the
    real heads is unchanged."""
    cfg = get_config("starcoder2_7b", smoke=True)  # 9 heads, kv 3
    params = init_tree(attention_specs(cfg), jax.random.PRNGKey(0))
    cfg_p = dataclasses.replace(cfg, head_pad=12)
    pp = init_tree(attention_specs(cfg_p), jax.random.PRNGKey(1))
    pp["wq"] = pp["wq"].at[:, :9].set(params["wq"])
    pp["wo"] = pp["wo"].at[:9].set(params["wo"])
    pp["wk"], pp["wv"] = params["wk"], params["wv"]
    x = _x(2, 32, cfg.d_model)
    pos = jnp.arange(32)[None]
    o1, _ = attention(params, x, cfg, positions=pos)
    o2, _ = attention(pp, x, cfg_p, positions=pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-3, rtol=1e-3)


def test_head_padding_zero_gradient():
    cfg = dataclasses.replace(get_config("starcoder2_7b", smoke=True),
                              head_pad=12)
    params = init_tree(attention_specs(cfg), jax.random.PRNGKey(2))
    x = _x(1, 16, cfg.d_model)
    pos = jnp.arange(16)[None]

    def loss(p):
        o, _ = attention(p, x, cfg, positions=pos)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    # padded wo rows get no gradient
    assert float(jnp.abs(g["wo"][9:]).max()) == 0.0


def test_chunked_ce_matches_unchunked():
    model = get_model("qwen3_4b", smoke=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(3))
    B, S = 2, 64
    x = _x(B, S, cfg.d_model).astype(jnp.bfloat16)
    labels = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    a = ce_loss(params, x, labels, cfg, chunk=16)
    from repro.models.layers import cross_entropy
    b = cross_entropy(_logits(params, x), labels, cfg.vocab).mean()
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


def test_zero2_state_axes():
    axes = {"w": ("embed", "ff"), "b": (None,)}
    z = opt.state_axes(axes, zero2=True)
    assert z["mu"]["w"] == ("opt_data", "ff")
    assert z["nu"]["b"] == (None,)
    plain = opt.state_axes(axes)
    assert plain["mu"]["w"] == ("embed", "ff")


def test_smoke_models_unaffected_by_full_config_flags():
    """Full configs carry head_pad/attn_q_chunk; smoke variants must not
    (they are the CPU correctness baseline)."""
    for arch in ("starcoder2_7b", "qwen3_4b", "dbrx_132b"):
        assert get_config(arch, smoke=True).attn_q_chunk == 0
    assert get_config("starcoder2_7b").head_pad == 48
    assert get_config("starcoder2_7b").attn_q_chunk == 2048


def test_kv_quant_decode_within_tolerance():
    """int8 KV cache (per-token dynamic scale) preserves decode logits
    to <2% relative error while halving the decode HBM stream."""
    cfg = get_config("qwen3_4b", smoke=True)
    from repro.models.registry import build_model
    from repro.models.layers import quantize_kv
    m = build_model(cfg)
    mq = build_model(dataclasses.replace(cfg, kv_quant=True))
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 500)
    _, (k, v) = m.prefill(params, {"tokens": toks[:, :15]})
    L, B, P, KV, D = k.shape
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    ck = jnp.zeros((L, B, 16, KV, D), jnp.bfloat16).at[:, :, :15].set(k)
    cv = jnp.zeros((L, B, 16, KV, D), jnp.bfloat16).at[:, :, :15].set(v)
    ckq = jnp.zeros((L, B, 16, KV, D), jnp.int8).at[:, :, :15].set(kq)
    cvq = jnp.zeros((L, B, 16, KV, D), jnp.int8).at[:, :, :15].set(vq)
    cks = jnp.ones((L, B, 16, KV, 1), jnp.float32).at[:, :, :15].set(ks)
    cvs = jnp.ones((L, B, 16, KV, 1), jnp.float32).at[:, :, :15].set(vs)
    batch = {"token": toks[:, 15:], "pos": jnp.full((2,), 15, jnp.int32)}
    lf, _ = m.decode_step(params, (ck, cv), batch)
    lq, cq = mq.decode_step(params, (ckq, cvq, cks, cvs), batch)
    rel = (float(jnp.abs(lf.astype(jnp.float32)
                         - lq.astype(jnp.float32)).max())
           / float(jnp.abs(lf.astype(jnp.float32)).max()))
    assert rel < 0.02
    assert len(cq) == 4 and cq[0].dtype == jnp.int8

    # cache_specs reflects the quantized layout
    sds, axes = mq.cache_specs(2, 16)
    assert len(sds) == 4 and sds[0].dtype == jnp.int8
