"""Batched execution + streaming metrics + stateful property tests for
the cache/expander interplay (hypothesis rule-based state machine)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import (RuleBasedStateMachine, given, initialize, invariant, rule,
                  settings, st)

from repro.core.cache import HBMCacheStore
from repro.core.expander import DRAMExpander, ExpanderConfig
from repro.models import get_model
from repro.serving.batching import (BatchAggregator, BatchedRankExecutor,
                                    BatchingConfig, PendingRank, bucket_of)
from repro.serving.metrics import P2Quantile, SLOTracker, WindowRate

RNG = np.random.default_rng(21)


# ---------------------------------------------------------------------------
# Batched rank execution == per-request execution
# ---------------------------------------------------------------------------


def test_batched_rank_matches_per_request():
    model = get_model("hstu_gr", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    ex = BatchedRankExecutor(model, params)
    batch = []
    singles = []
    for i, plen in enumerate((48, 64, 57)):  # mixed lengths, one bucket
        prefix = jnp.asarray(RNG.integers(0, 500, (1, plen)), jnp.int32)
        incr = RNG.integers(0, 500, 8).astype(np.int32)
        items = RNG.integers(0, 500, 16).astype(np.int32)
        _, psi = model.prefill(params, {"tokens": prefix})
        batch.append(PendingRank(user_id=i, psi=psi, prefix_len=plen,
                                 incr=incr, items=items))
        # per-request reference: same bucket-padded psi + normalizer
        k, v = psi
        pad = bucket_of(plen) - plen
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        singles.append(model.rank_with_cache(
            params, (kp, vp), jnp.asarray(incr[None]),
            jnp.asarray(items[None]))[0])
    outs = ex.run(batch)
    for got, want in zip(outs, singles):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=1e-4, rtol=1e-4)


def test_aggregator_batches_and_expiry():
    agg = BatchAggregator(BatchingConfig(max_batch=3, max_wait_ms=5.0))
    mk = lambda uid, plen: PendingRank(uid, None, plen,
                                       np.zeros(8, np.int32),
                                       np.zeros(16, np.int32))
    assert agg.add(mk(1, 100), now=0.0) is None
    assert agg.add(mk(2, 120), now=0.001) is None
    full = agg.add(mk(3, 90), now=0.002)
    assert full is not None and len(full) == 3           # same bucket (128)
    assert agg.add(mk(4, 5000), now=0.003) is None       # different bucket
    assert agg.expired(now=0.0031) == []
    exp = agg.expired(now=0.010)
    assert len(exp) == 1 and exp[0][0].user_id == 4


@given(st.integers(1, 40000))
def test_bucketing_monotone(n):
    b = bucket_of(n)
    assert b >= min(n, 32768)
    assert bucket_of(b) == b


# ---------------------------------------------------------------------------
# P2 quantile estimator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_p2_quantile_converges(q):
    rng = np.random.default_rng(3)
    data = rng.exponential(10.0, size=20000)
    est = P2Quantile(q)
    for x in data:
        est.add(float(x))
    true = np.quantile(data, q)
    assert abs(est.value - true) / true < 0.15


def test_p2_small_samples():
    est = P2Quantile(0.99)
    for x in (5.0, 1.0, 3.0):
        est.add(x)
    assert est.value == 5.0


def test_window_rate():
    w = WindowRate(window_s=10.0)
    for t in np.linspace(0, 10, 101):
        w.mark(float(t))
    assert w.rate(10.0) == pytest.approx(10.1, rel=0.05)
    assert w.rate(25.0) == 0.0


def test_slo_tracker_summary():
    tr = SLOTracker(slo_ms=100.0)
    for i in range(50):
        tr.observe(now=i * 0.01, e2e_ms=50.0 + i, hit="hbm_hit",
                   components={"rank": 10.0})
    s = tr.summary(now=0.5)
    assert s["n"] == 50
    assert 0.9 < s["success_rate"] <= 1.0
    assert s["hit_hbm_hit"] == 1.0
    assert s["rank_p99_ms"] == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# Stateful property test: HBM window + DRAM expander interplay
# ---------------------------------------------------------------------------


class CacheLifecycleMachine(RuleBasedStateMachine):
    """Random interleavings of insert/consume/spill/reload/evict must
    never violate: budget bounds, single-flight at-most-one, and
    no-user-in-two-tiers-simultaneously."""

    @initialize()
    def setup(self):
        self.hbm = HBMCacheStore(budget_bytes=50)
        self.exp = DRAMExpander(ExpanderConfig(dram_budget_bytes=100))
        self.clock = 0.0

    def _tick(self):
        self.clock += 0.01
        return self.clock

    @rule(uid=st.integers(0, 9), nbytes=st.integers(1, 20))
    def pre_infer(self, uid, nbytes):
        evicted = self.hbm.insert(uid, "psi", nbytes, self._tick(),
                                  prefix_len=uid)
        for e in evicted:
            if e.consumed:
                self.exp.spill(e)

    @rule(uid=st.integers(0, 9))
    def rank(self, uid):
        now = self._tick()
        action, entry = self.exp.pseudo_pre_infer(uid, self.hbm, now)
        if action == "hbm":
            self.hbm.consume(uid)
        elif action == "reload":
            self.exp.complete_reload(uid, self.hbm, now)
            self.exp.finish(uid)
            self.hbm.consume(uid)
        elif action in ("wait", "miss"):
            self.exp.finish(uid)

    @rule(uid=st.integers(0, 9))
    def spill_consumed(self, uid):
        e = self.hbm.entries.get(uid)
        if e is not None and e.consumed:
            import dataclasses as dc
            self.exp.spill(dc.replace(e))

    @invariant()
    def budgets_hold(self):
        assert 0 <= self.hbm.used_bytes <= 50
        assert 0 <= self.exp.used_bytes <= 100

    @invariant()
    def no_dangling_flight(self):
        # outside of a rule, no single-flight op should be left open
        assert all(v >= 0 for v in self.exp.flight._inflight.values())

    @invariant()
    def bytes_match_entries(self):
        assert self.hbm.used_bytes == sum(
            e.nbytes for e in self.hbm.entries.values())
        assert self.exp.used_bytes == sum(
            e.nbytes for e in self.exp.entries.values())


TestCacheLifecycle = CacheLifecycleMachine.TestCase
