"""Capacity harness: trace-realistic workloads + the matrix runner.

Covers the PR's tentpole end to end:

  * the Zipf popularity sampler — bounded inverse-CDF over a
    multi-million-user population, statistically hitting its
    configured skew (head share within tolerance of the analytic CDF),
    degenerating to uniform at skew=0;
  * pluggable arrival processes — Poisson / diurnal / MMPP all produce
    strictly increasing timestamps at approximately the offered rate;
  * ``UserBehaviorStore`` determinism — identical tokens/lengths for
    the same ``(user_id, trial)`` across *processes* with different
    ``PYTHONHASHSEED`` (the store must ride numpy's SeedSequence, not
    Python's salted ``hash``);
  * the shared knee-finder — geometric upper-bound expansion replaces
    the old hard ``hi=1200`` cap, with a backstop for degenerate
    always-passing criteria;
  * ``capacity_stream`` feeding ``ClusterSim.run`` unchanged, and
    ``run_point`` returning full latency distributions;
  * the declarative specs (``WorkloadSpec``/``MatrixSpec``) and the
    committed-report schema + the workload-provenance refusal and
    curve gates in ``benchmarks.check_regression``.
"""

import json
import subprocess
import sys

import numpy as np
import pytest

from repro.data.synthetic import (ARRIVAL_PROCESSES, UserBehaviorStore,
                                  ZipfPopularity, arrival_times,
                                  capacity_stream)

from benchmarks.capacity import (HARD_CAP_QPS, MatrixSpec, WorkloadSpec,
                                 cell_name, find_knee, headline, meets_slo,
                                 run_point)
from benchmarks.check_regression import (ProvenanceMismatch,
                                         check_provenance,
                                         compare_capacity,
                                         compare_isolation)
from benchmarks.check_regression import main as check_regression_main


# ---------------------------------------------------------------------------
# Zipf popularity
# ---------------------------------------------------------------------------


def test_zipf_head_share_matches_analytic_cdf():
    pop = ZipfPopularity(2_000_000, 1.1)
    ids = pop.sample(np.random.default_rng(0), 40_000)
    assert ids.min() >= 0 and ids.max() < 2_000_000
    for top in (100, 10_000):
        emp = float((ids < top).mean())
        assert emp == pytest.approx(pop.cdf(top), abs=0.02), \
            f"top-{top} share off: {emp} vs {pop.cdf(top)}"
    # a skew this heavy concentrates ~half the traffic on 100 users out
    # of two million — the regime where HBM hit rates finally move
    assert pop.cdf(100) > 0.4


def test_zipf_zero_skew_is_uniform():
    pop = ZipfPopularity(1_000_000, 0.0)
    ids = pop.sample(np.random.default_rng(1), 40_000)
    assert pop.cdf(500_000) == pytest.approx(0.5, abs=1e-5)
    assert float((ids < 500_000).mean()) == pytest.approx(0.5, abs=0.02)
    # virtually no repeats: the degenerate regime the old fixed_stream
    # pinned every mode's hit rate at 1.0 with
    assert len(np.unique(ids)) > 39_000


def test_zipf_validates_inputs():
    with pytest.raises(ValueError):
        ZipfPopularity(0, 1.0)
    with pytest.raises(ValueError):
        ZipfPopularity(100, -0.5)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("process", sorted(ARRIVAL_PROCESSES))
def test_arrivals_increasing_and_near_rate(process):
    ts = np.array(list(arrival_times(process, 200, 30.0,
                                     rng=np.random.default_rng(7))))
    assert np.all(np.diff(ts) > 0)
    assert ts[0] >= 0.0 and ts[-1] < 30.0
    # all processes target the offered rate on average (diurnal and
    # MMPP redistribute WHEN, not HOW MANY)
    assert len(ts) == pytest.approx(200 * 30.0, rel=0.15)


def test_mmpp_is_burstier_than_poisson():
    rng = np.random.default_rng(3)
    gaps = {p: np.diff(list(arrival_times(p, 300, 60.0, rng=rng)))
            for p in ("poisson", "mmpp")}
    cv2 = {p: np.var(g) / np.mean(g) ** 2 for p, g in gaps.items()}
    assert cv2["poisson"] == pytest.approx(1.0, abs=0.15)
    assert cv2["mmpp"] > cv2["poisson"] + 0.1


def test_unknown_arrival_process_rejected():
    with pytest.raises(ValueError):
        list(arrival_times("pareto", 10, 1.0, rng=np.random.default_rng(0)))
    with pytest.raises(ValueError):
        WorkloadSpec(skew=1.0, arrival="pareto")


# ---------------------------------------------------------------------------
# UserBehaviorStore determinism (hash-seed stability)
# ---------------------------------------------------------------------------

_PROBE = r"""
import json, sys
sys.path.insert(0, {src!r})
from repro.data.synthetic import UserBehaviorStore
s = UserBehaviorStore()
out = {{}}
for uid in (7, 123456789, 2**40 + 3):
    out[str(uid)] = {{
        "prefix_len": s.prefix_len(uid),
        "long_term": s.long_term(uid, 32).tolist(),
        "short_term": s.short_term(uid, trial=2).tolist(),
        "candidates": s.candidates(uid, trial=1, n_items=16).tolist(),
    }}
print(json.dumps(out, sort_keys=True))
"""


def _probe_store(hashseed: str) -> dict:
    import os
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    res = subprocess.run([sys.executable, "-c", _PROBE.format(src=src)],
                         capture_output=True, text=True, env=env,
                         check=True)
    return json.loads(res.stdout)


def test_behavior_store_deterministic_across_processes():
    """Same (user_id, trial) must yield identical tokens and lengths
    in fresh interpreters with different hash seeds: the synthetic
    workload is part of the benchmark's provenance, so it may not
    depend on process-local state."""
    a = _probe_store("0")
    b = _probe_store("424242")
    assert a == b
    # and the in-process store agrees with both
    s = UserBehaviorStore()
    assert s.prefix_len(7) == a["7"]["prefix_len"]
    assert s.long_term(7, 32).tolist() == a["7"]["long_term"]


def test_behavior_store_trials_differ():
    s = UserBehaviorStore()
    assert s.short_term(7, trial=0).tolist() != \
        s.short_term(7, trial=1).tolist()
    assert s.candidates(7, trial=0).tolist() != \
        s.candidates(8, trial=0).tolist()


# ---------------------------------------------------------------------------
# knee finder
# ---------------------------------------------------------------------------


def _step_service(capacity):
    """Synthetic service: meets SLO iff offered <= capacity."""
    def measure(q):
        return {"goodput_qps": min(q, capacity), "offered": q,
                "ok": q <= capacity}
    return measure


def test_knee_expands_past_old_hard_cap():
    """S1: the old bisection clamped hi at 1200 QPS — a service whose
    knee sits above that must still be found."""
    res = find_knee(_step_service(5000), lambda s: s["ok"])
    assert not res.capped
    assert res.best == pytest.approx(5000, rel=0.09)
    assert res.knee_qps <= 5000 + 1e-9
    probed = [q for q, ok, _ in res.probes]
    assert max(probed) > 1200


def test_knee_finds_low_capacity_service():
    res = find_knee(_step_service(40), lambda s: s["ok"])
    assert res.best == pytest.approx(40, rel=0.15)


def test_knee_caps_degenerate_always_passing_criterion():
    res = find_knee(_step_service(float("inf")), lambda s: True,
                    hard_cap=10_000)
    assert res.capped
    assert res.knee_qps == pytest.approx(10_000)
    assert HARD_CAP_QPS >= 1e6  # the real backstop is far out of reach


def test_knee_grounds_bracket_when_seed_hi_fails():
    """Regression: a service whose knee sits below every bisection
    midpoint used to report knee_qps=0/best=0 without ever probing
    ``lo`` — the bracket's lower bound was assumed, not measured.  A
    capacity of 5.5 (just above the default lo=5) must be FOUND, and
    the grounding probe at lo recorded."""
    res = find_knee(_step_service(5.5), lambda s: s["ok"])
    assert res.knee_qps >= 5.0, "lo passed but was never probed"
    assert res.best == pytest.approx(5.0, abs=0.6)
    assert any(q == pytest.approx(5.0) and ok for q, ok, _ in res.probes)


def test_knee_reports_zero_when_even_lo_fails():
    """When lo itself fails the criterion there is genuinely no
    measured capacity: knee 0, and the lo probe is in the evidence."""
    res = find_knee(_step_service(1.0), lambda s: s["ok"])
    assert res.knee_qps == 0.0 and res.best == 0.0
    assert any(q == pytest.approx(5.0) and not ok
               for q, ok, _ in res.probes)


# ---------------------------------------------------------------------------
# capacity stream -> simulator
# ---------------------------------------------------------------------------


def test_capacity_stream_is_seeded_and_skewed():
    def draw(seed):
        return [(t, m.user_id) for t, m in
                capacity_stream(2048, 50, 4.0, skew=1.1, seed=seed)]
    assert draw(0) == draw(0)
    assert draw(0) != draw(1)
    uids = [u for _, u in draw(0)]
    assert any(uids.count(u) > 1 for u in set(uids)), \
        "a skewed stream this long must repeat hot users"


def test_run_point_skewed_workload_distribution():
    wl = WorkloadSpec(skew=1.1, arrival="poisson")
    s = run_point("relay_batched", 2048, 120, workload=wl, dur=3.0,
                  distribution=True)
    for f in ("p50_ms", "p99_ms", "mean_ms", "p90_ms", "p95_ms",
              "max_ms", "hbm_hit", "goodput_qps", "success_rate"):
        assert f in s, f
    assert s["n"] > 100
    assert s["p50_ms"] <= s["p90_ms"] <= s["p99_ms"] <= s["max_ms"]
    assert meets_slo(s)


# ---------------------------------------------------------------------------
# declarative specs + committed-report schema
# ---------------------------------------------------------------------------


def test_workload_spec_roundtrip_and_name():
    wl = WorkloadSpec(skew=1.1, arrival="mmpp")
    assert wl.name == "zipf1.1-mmpp"
    assert WorkloadSpec.from_dict(wl.to_dict()) == wl
    assert WorkloadSpec(0.0, "poisson").name == "uniform-poisson"
    assert wl.head_share(100) > 0.4


def test_matrix_spec_roundtrip_and_quick_subset():
    full, quick = MatrixSpec(), MatrixSpec.quick_spec()
    assert MatrixSpec.from_dict(full.to_dict()) == full
    full_cells = {cell_name(m, L, w, h) for m, L, w, h in full.cell_keys()}
    quick_cells = {cell_name(m, L, w, h)
                   for m, L, w, h in quick.cell_keys()}
    # the CI smoke gates against the committed full matrix over the
    # cell-name intersection — quick must be a strict subset
    assert quick_cells and quick_cells < full_cells
    assert quick.quick and not full.quick


def test_headline_schema_and_provenance_gate():
    spec = MatrixSpec.quick_spec()
    cells = {"relay/L2048/zipf1.1-poisson": {
        "mode": "relay", "L": 2048, "workload_name": "zipf1.1-poisson",
        "knee_qps": 100.0, "knee_goodput_qps": 98.0,
        "curve": [{"offered_qps": 50.0, "goodput_qps": 49.0},
                  {"offered_qps": 100.0, "goodput_qps": 97.0}]}}
    head = headline(cells, spec)
    for f in ("seed", "population", "slo_ms", "sim_s", "quick",
              "arrivals", "skews", "matrix"):
        assert f in head["meta"], f
    # same provenance diffs fine; a reseeded candidate is refused
    check_provenance(head, head, ("seed", "population", "slo_ms"))
    other = {"meta": dict(head["meta"], seed=99), "cells": cells}
    with pytest.raises(ProvenanceMismatch):
        check_provenance(head, other, ("seed", "population", "slo_ms"))


def test_compare_capacity_knee_floor_and_monotone_curve():
    ref = {"cells": {"c": {"knee_qps": 100.0, "curve": [
        {"offered_qps": 50.0, "goodput_qps": 50.0},
        {"offered_qps": 100.0, "goodput_qps": 99.0}]}}}
    good = {"cells": {"c": {"knee_qps": 95.0, "curve": [
        {"offered_qps": 50.0, "goodput_qps": 50.0},
        {"offered_qps": 95.0, "goodput_qps": 94.0}]}}}
    rows = compare_capacity(ref, good, knee_floor=0.85, curve_tol=0.02)
    assert all(ok for *_, ok in rows)
    # knee collapse fails the floor
    slow = {"cells": {"c": {"knee_qps": 60.0, "curve": [
        {"offered_qps": 60.0, "goodput_qps": 60.0}]}}}
    rows = compare_capacity(ref, slow, knee_floor=0.85, curve_tol=0.02)
    assert any(f == "knee_qps" and not ok for _, f, *_, ok in rows)
    # a goodput dip below the knee fails the shape gate
    dip = {"cells": {"c": {"knee_qps": 100.0, "curve": [
        {"offered_qps": 50.0, "goodput_qps": 50.0},
        {"offered_qps": 75.0, "goodput_qps": 30.0},
        {"offered_qps": 100.0, "goodput_qps": 99.0}]}}}
    rows = compare_capacity(ref, dip, knee_floor=0.85, curve_tol=0.02)
    assert any("monotone" in f and not ok for _, f, *_, ok in rows)
    # disjoint cells cannot be gated at all
    rows = compare_capacity(ref, {"cells": {}}, knee_floor=0.85,
                            curve_tol=0.02)
    assert rows == [("capacity", "<cells>", 1, 0,
                     "cell-key intersection non-empty", False)]


def test_compare_capacity_mmpp_cells_exempt_from_shape_gates():
    """Bursty-arrival cells keep the knee floor but skip the
    Poisson-only inferences (goodput monotonicity, cold knee lift):
    MMPP burst phase realigns with every offered-rate rescale, so a
    sub-knee goodput dip there is alignment noise, not admission
    collapse."""
    dip_curve = [{"offered_qps": 50.0, "goodput_qps": 50.0},
                 {"offered_qps": 75.0, "goodput_qps": 30.0},
                 {"offered_qps": 100.0, "goodput_qps": 99.0}]
    mk = lambda arrival, knee=100.0: {
        "knee_qps": knee, "curve": dip_curve,
        "workload": {"skew": 1.1, "arrival": arrival}}
    ref = {"cells": {
        "relay_cold/L2048/zipf1.1-mmpp": mk("mmpp", knee=60.0),
        "relay_batched/L2048/zipf1.1-mmpp": mk("mmpp", knee=100.0),
        "relay_cold/L2048/zipf1.1-poisson": mk("poisson"),
        "relay_batched/L2048/zipf1.1-poisson": mk("poisson", knee=90.0)}}
    rows = compare_capacity(ref, ref, knee_floor=0.85, curve_tol=0.02)
    by_cell = {}
    for mode, field, *_, ok in rows:
        by_cell.setdefault(mode, {})[field] = ok
    mmpp = by_cell["relay_cold/L2048/zipf1.1-mmpp"]
    poisson = by_cell["relay_cold/L2048/zipf1.1-poisson"]
    # knee floor gates everyone; the shape gates only the poisson cell
    assert mmpp["knee_qps"] and poisson["knee_qps"]
    assert "goodput monotone to knee" not in mmpp
    assert not poisson["goodput monotone to knee"]       # the dip fails
    # cold knee lift: skipped for mmpp (60 < 100 would fail), enforced
    # and passing for poisson (100 >= 90)
    lift = [f for f in poisson if f.startswith("knee_qps >=")]
    assert lift and poisson[lift[0]]
    assert not any(f.startswith("knee_qps >=") for f in mmpp)


# ---------------------------------------------------------------------------
# multi-tenant isolation gates + schema-drift refusal
# ---------------------------------------------------------------------------


def test_compare_isolation_gates_burst_shift():
    """The burst-isolation gate: tenant B's MMPP burst moving tenant
    A's hit rate or knee past tolerance fails; a missing record is a
    FAIL (the gate demands evidence), never a silent pass."""
    iso = {"solo": {"hit_rate": 0.93, "knee_qps": 560.0},
           "burst": {"hit_rate": 0.935, "knee_qps": 560.0}}
    rows = compare_isolation({"isolation": iso}, {},
                             hit_tol=0.02, knee_tol=0.10)
    assert rows and all(ok for *_, ok in rows)
    # B's burst stealing A's cache fails the hit gate
    moved = {"isolation": dict(iso, burst={"hit_rate": 0.80,
                                           "knee_qps": 560.0})}
    rows = compare_isolation(moved, {}, hit_tol=0.02, knee_tol=0.10)
    assert any(f == "tenant A hit_rate under B burst" and not ok
               for _, f, *_, ok in rows)
    # A's knee collapsing under the burst fails the knee gate
    knee = {"isolation": dict(iso, burst={"hit_rate": 0.93,
                                          "knee_qps": 300.0})}
    rows = compare_isolation(knee, {}, hit_tol=0.02, knee_tol=0.10)
    assert any(f == "tenant A knee_qps under B burst" and not ok
               for _, f, *_, ok in rows)
    # both records gated when both sides carry one
    rows = compare_isolation({"isolation": iso}, {"isolation": iso},
                             hit_tol=0.02, knee_tol=0.10)
    assert {r[0] for r in rows} == {"isolation[committed]",
                                    "isolation[candidate]"}
    # no record anywhere: a FAIL row, not a pass
    rows = compare_isolation({}, {}, hit_tol=0.02, knee_tol=0.10)
    assert rows == [("isolation", "<record>", "present", "MISSING",
                     "committed isolation record required", False)]


def test_capacity_candidate_without_quick_flag_refused(tmp_path, capsys):
    """Schema-drift refusal: a capacity candidate whose meta lacks the
    ``quick`` flag entirely cannot be told apart from a smoke run, so
    the gate refuses it (exit 2 with a message naming the flag) instead
    of diffing under arbitrary tolerances."""
    cell = {"knee_qps": 100.0,
            "curve": [{"offered_qps": 50.0, "goodput_qps": 50.0}],
            "workload": {"skew": 1.1, "arrival": "poisson"}}
    iso = {"solo": {"hit_rate": 0.9, "knee_qps": 100.0},
           "burst": {"hit_rate": 0.9, "knee_qps": 100.0}}
    meta = {"seed": 0, "population": 1, "slo_ms": 300.0}
    ref = tmp_path / "ref.json"
    ref.write_text(json.dumps(
        {"meta": dict(meta, quick=False), "cells": {"c": cell},
         "isolation": iso}))
    cand = tmp_path / "cand.json"
    cand.write_text(json.dumps({"meta": meta, "cells": {"c": cell}}))
    rc = check_regression_main(["--capacity-candidate", str(cand),
                                "--capacity-reference", str(ref)])
    assert rc == 2
    assert "meta.quick" in capsys.readouterr().err
    # the SAME candidate with the flag present clears the refusal and
    # reaches the tolerance gates (identical cells: all pass)
    cand.write_text(json.dumps(
        {"meta": dict(meta, quick=True), "cells": {"c": cell},
         "isolation": iso}))
    rc = check_regression_main(["--capacity-candidate", str(cand),
                                "--capacity-reference", str(ref)])
    assert rc == 0
