"""Substrate tests: MoE dispatch, optimizer, data pipeline, partitioning
rules, checkpointing, cost-model/flop accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import GRCostModel
from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
from repro.models import get_config, get_model
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy
from repro.models.moe import _expert_compute, moe_ffn
from repro.models.partitioning import Rules
from repro.training import checkpoint
from repro.training import optimizer as opt


# ---------------------------------------------------------------------------
# MoE dispatch correctness
# ---------------------------------------------------------------------------


def test_moe_capacity_dispatch_matches_dense_reference():
    """With ample capacity, the scatter/gather dispatch equals the naive
    per-token expert sum."""
    rng = np.random.default_rng(0)
    T, d, f, E, k = 32, 16, 24, 4, 2
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)
    gates = jnp.asarray(rng.random((T, k)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)

    out = _expert_compute(x, gates, eidx, wi, wg, wo, 0, capacity=T * k,
                          act=jax.nn.silu)

    ref = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(k):
            e = int(eidx[t, j])
            h = jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wi[e])
            ref[t] += float(gates[t, j]) * np.asarray(h @ wo[e])
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_overflow():
    """Over capacity, tokens are dropped (contribute zero), never wrong."""
    T, d, f, E, k = 16, 8, 8, 2, 1
    x = jnp.ones((T, d))
    wi = wg = jnp.ones((E, d, f)) * 0.1
    wo = jnp.ones((E, f, d)) * 0.1
    gates = jnp.ones((T, k))
    eidx = jnp.zeros((T, k), jnp.int32)  # all tokens -> expert 0
    out_cap2 = _expert_compute(x, gates, eidx, wi, wg, wo, 0, 2, jax.nn.silu)
    nonzero = (np.abs(np.asarray(out_cap2)).sum(-1) > 0).sum()
    assert nonzero == 2


def test_moe_aux_loss_uniform_router_is_minimal():
    model = get_model("deepseek_moe_16b", smoke=True)
    cfg = model.cfg
    x = jnp.ones((2, 8, cfg.d_model), jnp.bfloat16) * 0.01
    params = model.init(jax.random.PRNGKey(0))
    p0 = jax.tree.map(jnp.copy, params["layers"]["moe"])
    p0 = jax.tree.map(lambda t: t[0], p0)
    _, aux = moe_ffn(p0, x, cfg)
    assert float(aux) >= 0.0


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=0.02)


def test_grad_clip_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                          total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = opt.init_state(params)
    _, _, m = opt.apply_updates(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_behavior_store_deterministic():
    s1, s2 = UserBehaviorStore(), UserBehaviorStore()
    for uid in (0, 7, 12345):
        np.testing.assert_array_equal(s1.long_term(uid), s2.long_term(uid))
        assert s1.prefix_len(uid) == s2.prefix_len(uid)


def test_length_distribution_matches_paper():
    """<6% of users exceed 2K tokens (paper §4.1)."""
    store = UserBehaviorStore()
    lens = np.array([store.prefix_len(u) for u in range(4000)])
    frac_long = (lens > 2048).mean()
    assert 0.005 < frac_long < 0.08


def test_train_batches_shapes():
    store = UserBehaviorStore(WorkloadConfig(vocab=1000))
    b = next(store.train_batches(4, 32))
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    assert b["tokens"].max() < 1000


# ---------------------------------------------------------------------------
# Partitioning rules
# ---------------------------------------------------------------------------


def test_rules_divisibility_fallback():
    rules = Rules(None)
    # no mesh -> everything unsharded at constrain time
    assert rules.mesh is None


def test_rules_spec_drops_indivisible():
    class FakeMesh:  # 16-way model axis without 256 devices
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    r = Rules.__new__(Rules)
    r.mesh = FakeMesh()
    r.fsdp = False
    r.table = {"batch": "data", "heads": "model", "ff": "model"}
    spec36 = r.spec(("batch", None, "heads", None), shape=(256, 1, 36, 128))
    assert spec36[2] is None              # 36 heads % 16 -> replicated
    spec48 = r.spec(("batch", None, "heads", None), shape=(256, 1, 48, 128))
    assert spec48[2] == "model"


def test_rules_no_duplicate_mesh_axes():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    r = Rules.__new__(Rules)
    r.mesh = FakeMesh()
    r.fsdp = False
    r.table = {"heads": "model", "ff": "model"}
    spec = r.spec(("heads", "ff"), shape=(48, 1024))
    # "model" may appear at most once in one spec
    assert [s for s in spec if s == "model"] == ["model"]


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    model = get_model("qwen3_4b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init_state(params)
    checkpoint.save(tmp_path / "ck", params, state, step=7)
    (restored, step) = checkpoint.restore(
        tmp_path / "ck", {"params": params, "opt": state})
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# FLOP accounting & cross entropy
# ---------------------------------------------------------------------------


def test_jaxpr_flops_counts_scan_trips():
    from repro.launch.flops import step_flops

    def f(x, w):
        def body(c, wl):
            return c @ wl, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    sds = (jax.ShapeDtypeStruct((64, 64), jnp.float32),
           jax.ShapeDtypeStruct((10, 64, 64), jnp.float32))
    fl = step_flops(f, sds)
    assert fl == pytest.approx(10 * 2 * 64**3)


def test_cross_entropy_vocab_padding_masked():
    logits = jnp.zeros((2, 3, 16))
    labels = jnp.array([[0, 1, 2], [3, 4, 5]])
    ce_pad = cross_entropy(logits, labels, vocab=10)
    # same logits without padding region
    ce_ref = cross_entropy(logits[..., :10], labels, vocab=10)
    np.testing.assert_allclose(np.asarray(ce_pad), np.asarray(ce_ref),
                               atol=1e-5)


def test_costmodel_paper_table1():
    cost = GRCostModel(get_config("hstu_gr"))
    assert cost.kv_bytes(2048) == 32 * 2**20  # 32 MiB (paper Table 1)
