"""Cluster-simulator behaviour: the paper's qualitative claims must hold
in the discrete-event model before the benchmarks quantify them."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.costmodel import GRCostModel
from repro.core.runtime import relay_config
from repro.core.trigger import TriggerConfig
from repro.core.types import UserMeta
from repro.data.synthetic import UserBehaviorStore, request_stream
from repro.models import get_config
from repro.serving.simulator import run_sim

COST = GRCostModel(get_config("hstu_gr"))


def _fixed(L, qps, dur=8.0, seed=0, refresh=0.0, horizon=6000):
    rng = np.random.default_rng(seed)
    t, recent = 0.0, []
    while t < dur:
        t += rng.exponential(1.0 / qps)
        if recent and rng.random() < refresh:
            uid = int(rng.choice(recent[-horizon:]))
        else:
            uid = int(rng.integers(0, 10**9))
        recent.append(uid)
        yield t, UserMeta(user_id=uid, prefix_len=L)


def _cfg(relay, dram=0.0, r2=0.8):
    return relay_config(trigger=TriggerConfig(n_instances=5, r2=r2,
                                              kv_p99_len=4096),
                        relay_enabled=relay, dram_budget_bytes=dram,
                        hbm_cache_bytes=2e9)


def test_relay_beats_baseline_on_long_sequences():
    base = run_sim(_cfg(False, r2=0.2), COST, _fixed(4096, 50))
    relay = run_sim(_cfg(True), COST, _fixed(4096, 50))
    assert relay["p99_ms"] < base["p99_ms"]
    assert relay["hbm_hit"] > 0.5


def test_all_requests_complete():
    arr = list(_fixed(4096, 80))
    s = run_sim(_cfg(True), COST, iter(arr))
    assert s["n"] == len(arr)


def test_out_of_order_single_reload_per_burst():
    """Rapid same-user refresh burst: pseudo-pre-infer + single-flight
    keep DRAM->HBM reloads at <= one per burst (paper §3.4)."""
    meta = UserMeta(user_id=42, prefix_len=4096)
    arr = [(0.001 * i, meta) for i in range(6)]
    cfg = _cfg(True, dram=500e9)
    from repro.serving.simulator import ClusterSim
    sim = ClusterSim(cfg, COST)
    sim.run(iter(arr))
    inst = [i for i in sim.instances.values()
            if i.expander.stats["spills"] or i.hbm.stats["inserts"]]
    assert inst, "no instance touched"
    total_pre_plus_reloads = sum(
        i.expander.stats["reloads"] for i in sim.instances.values())
    assert total_pre_plus_reloads <= 1


def test_dram_tier_extends_reuse():
    relay = run_sim(_cfg(True), COST,
                    _fixed(4096, 120, refresh=0.6))
    dram = run_sim(_cfg(True, dram=500e9), COST,
                   _fixed(4096, 120, refresh=0.6))
    assert dram["dram_hit"] >= relay["dram_hit"]
    assert dram["miss"] <= relay["miss"] + 0.05


def test_premature_evictions_zero_under_admission_control():
    """Invariant I2: with the trigger bounding the live-cache footprint,
    no admitted cache is evicted before its ranking consumes it."""
    from repro.serving.simulator import ClusterSim
    sim = ClusterSim(_cfg(True), COST, )
    sim.run(_fixed(4096, 100, dur=10.0))
    for inst in sim.instances.values():
        assert inst.hbm.stats["premature_evictions"] == 0


@given(st.integers(1024, 8192))
@settings(max_examples=5, deadline=None)
def test_utilisation_bounded(L):
    s = run_sim(_cfg(True), COST, _fixed(L, 40, dur=5.0))
    assert 0.0 <= s["special_util"] <= 1.0 + 1e-6
