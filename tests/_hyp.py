"""Import-or-stub shim for hypothesis.

The property-based tests are a tier-2 nicety: on minimal environments
(no ``hypothesis`` wheel baked into the image) the suite must still
collect and run the example-based tests.  Importing from this module
instead of ``hypothesis`` directly gives each test file:

  * the real ``given``/``settings``/``st``/stateful API when hypothesis
    is installed (``HAS_HYPOTHESIS = True``);
  * skip-marked no-op stand-ins otherwise, so property tests report as
    skipped instead of exploding at collection time.
"""

from __future__ import annotations

import unittest

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call; the value is never used
        because the stubbed ``given`` replaces the test body."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    class settings:  # noqa: N801 - mirrors the hypothesis name
        def __init__(self, *_a, **_k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    @unittest.skip("hypothesis not installed")
    class _SkippedMachineCase(unittest.TestCase):
        pass

    class RuleBasedStateMachine:
        TestCase = _SkippedMachineCase

    def rule(*_a, **_k):
        return lambda fn: fn

    def initialize(*_a, **_k):
        return lambda fn: fn

    def invariant(*_a, **_k):
        return lambda fn: fn


__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st",
           "RuleBasedStateMachine", "initialize", "invariant", "rule"]
