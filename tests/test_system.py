"""End-to-end behaviour tests for the RelayGR system (live + sim)."""

import jax
import numpy as np
import pytest

from repro.core import (GRCostModel, LiveExecutor, RelayGRService,
                        TriggerConfig, relay_config)
from repro.core.types import HitKind
from repro.data.synthetic import (UserBehaviorStore, WorkloadConfig,
                                  request_stream)
from repro.models import build_model, get_config

COST = GRCostModel(get_config("hstu_gr"))


def _svc(**kw):
    return RelayGRService(
        relay_config(trigger=TriggerConfig(n_instances=10, **kw)), COST)


def test_admitted_requests_always_hit_locally():
    """Invariant I1 path: with affinity intact, every admitted request
    consumes psi locally (no remote fetch exists in the system at all —
    the assert is that admitted => HBM/DRAM hit, not fallback)."""
    svc = _svc()
    store = UserBehaviorStore()
    admitted_uids = set()
    results = {}
    for uid in range(800):
        meta = store.meta(uid)
        sig = svc.on_retrieval(meta, now=uid * 0.01)
        if sig is not None:
            svc.deliver_pre_infer(sig, now=uid * 0.01)
            admitted_uids.add(meta.user_id)
        results[uid] = svc.on_rank(meta, now=uid * 0.01 + 1e-3)
    assert admitted_uids, "workload produced no admits"
    for uid in admitted_uids:
        assert results[uid].hit in (HitKind.HBM_HIT, HitKind.DRAM_HIT), \
            f"admitted user {uid} fell back"


def test_affinity_disruption_falls_back_not_fails():
    """Churn: removing the cache-holding instance after pre-infer makes
    ranking fall back to full inference — correct result, lost speedup."""
    svc = _svc()
    store = UserBehaviorStore()
    sig, meta = None, None
    for uid in range(500):
        meta = store.meta(uid)
        sig = svc.on_retrieval(meta, now=0.0)
        if sig is not None:
            break
    assert sig is not None
    svc.deliver_pre_infer(sig, now=0.0)
    holder = sig.body["target"]
    from repro.core.engine import RankingInstance
    svc.router.remove_special(holder)
    svc.router.add_special("special-new")
    svc.instances["special-new"] = RankingInstance(
        svc.instances[holder].cfg, svc.instances[holder].executor)
    svc.instances["special-new"].name = "special-new"
    r = svc.on_rank(meta, now=0.1)
    # either re-routed to a cold instance (fallback) or the hash ring
    # still maps to a surviving holder — both are correct outcomes
    assert r.hit in (HitKind.MISS_FALLBACK, HitKind.HBM_HIT)


def test_short_traffic_untouched():
    """Safe requests take the normal service with zero added work."""
    svc = _svc()
    meta = UserBehaviorStore().meta(3)
    meta.prefix_len = 32
    sig = svc.on_retrieval(meta, now=0.0)
    assert sig is None
    r = svc.on_rank(meta, now=0.0)
    assert r.instance.startswith("normal")


def test_live_service_end_to_end():
    """Real JAX compute through the full relay (smoke model)."""
    cfg = get_config("hstu_gr", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=32, incr_len=8, len_mu=7.2, len_sigma=0.6,
        max_len=2048))
    svc = RelayGRService(
        relay_config(trigger=TriggerConfig(
            n_instances=4, r2=0.5, rank_p99_budget_ms=10.0)),
        COST,
        executor_factory=lambda name: LiveExecutor(model, params, store))
    hits = []
    for i, (t, meta) in enumerate(request_stream(store, 50, 1e9, seed=1)):
        if i >= 12:
            break
        r = svc.submit(meta, now=t)
        hits.append(r.hit)
        if r.hit != HitKind.MISS_FALLBACK:
            assert r.scores is not None
            assert np.isfinite(np.asarray(r.scores, np.float32)).all()
    assert any(h == HitKind.HBM_HIT for h in hits), \
        "no request exercised the relay path"
