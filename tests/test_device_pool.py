"""Device-resident page pool — parity, ledger, and launch contracts.

The ``DevicePagePool`` keeps the page-pool data plane on device and
mutates it in place (donated scatter at insert/resume); correctness is
defined relative to the host-buffer pool:

  * after ANY interleaving of insert / partial tail-evict /
    resume-reload / extract-handoff, the device mirror is byte-equal to
    the host buffer on every page a launch could reference (live or
    pinned), page accounting is conserved, and the null page stays
    zero — so gathered K/V, and therefore scores, bit-match the
    host-buffer path (hypothesis-driven via ``tests/_hyp``, plus a
    deterministic interleaving that always runs);
  * end to end through ``RelayRuntime``, the device-pool deployment
    scores bit-identically to the host-buffer deployment while its
    ``h2d`` ledger reads ``launch_reships == 0`` and
    ``bytes_scattered`` == the freshly inserted page bytes (the
    host-buffer deployment re-ships the pool once per launch);
  * ``_page_launch_args`` REFUSES to truncate a page table wider than
    the launch bucket (the silent-drop bugfix), and ``rank_group``
    widens its bucket to the largest member so an entry whose
    whole-page span padding overhangs the prefix bucket still gathers
    every cached page.
"""

import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import (BatchingConfig, ClusterConfig, DevicePagePool,
                        GRCostModel, HitKind, PageLayout, TriggerConfig,
                        UserMeta, get_executor, relay_config)
from repro.core.cache import PagedHBMStore, kv_nbytes
from repro.core.runtime import RelayRuntime
from repro.models import get_config

N_LAYERS = 2
H, D = 2, 3
PT = 8
LAYOUT = PageLayout(page_tokens=PT, slabs=2 * N_LAYERS,
                    token_bytes=H * D * 4)
POOL_PAGES = 40


def _tokens_of(uid: int) -> int:
    # fixed per user (so a re-insert is a refresh/resume, never a
    # resize) and deliberately page-unaligned
    return 2 * PT * (1 + uid % 3) - 3


def _kv(uid: int, tokens: int):
    rng = np.random.default_rng(uid * 1009 + tokens)
    shape = (N_LAYERS, 1, tokens, H, D)
    return (rng.standard_normal(shape).astype(np.float32),
            rng.standard_normal(shape).astype(np.float32))


def _store(device: bool) -> PagedHBMStore:
    return PagedHBMStore(POOL_PAGES * LAYOUT.page_bytes, LAYOUT,
                         device_pool=device)


def _resident_pages(store: PagedHBMStore, entry) -> np.ndarray:
    pps = store.layout.pages_per_slab(entry.tokens_resident) \
        if entry.tokens_resident else 0
    return entry.page_table[:, :pps].reshape(-1)


def _check_mirror_and_conservation(store: PagedHBMStore, pinned) -> None:
    pool = store.pool
    assert pool.stats["pages_allocated"] == \
        pool.pages_live + pool.stats["pages_freed"]
    assert pool.h2d["launch_reships"] == 0
    assert pool.h2d["bytes_scattered"] == \
        pool.h2d["pages_scattered"] * pool.page_bytes
    if not isinstance(pool, DevicePagePool) or pool.device_buffer is None:
        return
    dev = np.asarray(pool.device_buffer)
    assert not dev[pool.n_pages].any(), "null page must stay zero"
    for e in store.entries.values():
        if e.page_table is None:
            continue
        pages = _resident_pages(store, e)
        assert dev[pages].tobytes() == store.buffer[pages].tobytes()
    for psi in pinned:
        # an in-flight launch's pinned snapshot stays readable and
        # byte-stable even after the window freed/recycled around it
        assert dev[psi.table.reshape(-1)].tobytes() == \
            store.buffer[psi.table.reshape(-1)].tobytes()


def _drive_pair(ops):
    """Apply one op sequence to a host-buffer store and a device-pool
    store; after every step the device mirror must bit-match the host
    data plane and both stores must agree entry-for-entry."""
    host, dev = _store(False), _store(True)
    pinned = {id(host): [], id(dev): []}
    now = 0.0
    for op, uid in ops:
        now += 1.0
        tokens = _tokens_of(uid)
        for s in (host, dev):
            if op == "insert":
                v = _kv(uid, tokens)
                s.insert(uid, v, kv_nbytes(v), now, prefix_len=tokens)
            elif op == "consume":
                s.consume(uid)
            elif op == "back":
                e = s.entries.get(uid)
                if e is not None and e.consumed:
                    e.dram_backed = True   # runtime spilled a DRAM copy
            elif op == "extract":
                s.extract(uid)
            elif op == "pop":
                s.pop(uid)
            elif op == "pin":
                e = s.resident(uid)
                if e is not None:
                    pinned[id(s)].append(s.acquire_value(e))
            elif op == "release" and pinned[id(s)]:
                s.release_value(pinned[id(s)].pop(0))
        _check_mirror_and_conservation(dev, pinned[id(dev)])
        # identical window decisions on both flavours...
        assert sorted(host.entries) == sorted(dev.entries)
        assert host.stats == dev.stats
        for uid_, he in host.entries.items():
            de = dev.entries[uid_]
            assert he.tokens_resident == de.tokens_resident
            # ...and identical page data (the score-determining input)
            if he.page_table is not None and host.buffer is not None:
                hp, dp = _resident_pages(host, he), _resident_pages(dev, de)
                assert host.buffer[hp].tobytes() == dev.buffer[dp].tobytes()
    return host, dev


# deterministic interleaving covering every path: fills the window,
# partial tail-evicts a consumed DRAM-backed victim, resumes it,
# hands one entry off, and recycles freed pages under a live pin
DETERMINISTIC_OPS = [
    ("insert", 2), ("consume", 2), ("back", 2),
    ("insert", 0), ("insert", 1),          # pressure -> partial tail evict
    ("insert", 2),                         # resume-reload of user 2's tail
    ("pin", 1), ("extract", 1),            # handoff under an active launch
    ("insert", 3), ("insert", 4),          # realloc over recycled pages
    ("release", 1), ("insert", 5), ("pop", 0), ("insert", 0),
]


def test_device_pool_interleaving_parity_deterministic():
    host, dev = _drive_pair(DETERMINISTIC_OPS)
    assert dev.stats["partial_evictions"] >= 1, "tail evict not exercised"
    assert dev.stats["resumed_reloads"] >= 1, "resume not exercised"
    assert dev.stats["handoffs"] >= 1, "extract-handoff not exercised"
    assert dev.pool.stats["pages_freed"] > 0
    assert dev.pool.h2d["scatters"] > 0


def test_device_pool_resume_scatters_only_missing_tail():
    """A resumed partial reload lands only the missing tail pages on
    the device — the resident head never re-crosses the link."""
    _, dev = _drive_pair(DETERMINISTIC_OPS[:5])   # user 2 partially evicted
    e = dev.entries[2]
    assert e.tokens_resident < e.prefix_len
    before = dict(dev.pool.h2d)
    v = _kv(2, _tokens_of(2))
    dev.insert(2, v, kv_nbytes(v), 99.0, prefix_len=_tokens_of(2))
    assert dev.stats["resumed_reloads"] == 1
    moved = dev.pool.h2d["pages_scattered"] - before["pages_scattered"]
    assert 0 < moved < LAYOUT.entry_pages(_tokens_of(2))
    assert dev.pool.h2d["bytes_scattered"] - before["bytes_scattered"] == \
        moved * LAYOUT.page_bytes


OPS = st.lists(
    st.tuples(st.sampled_from(["insert", "consume", "back", "extract",
                               "pop", "pin", "release"]),
              st.integers(0, 5)),
    max_size=60)


@given(OPS)
@settings(max_examples=40, deadline=None)
def test_device_pool_interleaving_parity_property(ops):
    _drive_pair(ops)


@given(st.lists(st.integers(0, 5), min_size=8, max_size=40))
@settings(max_examples=40, deadline=None)
def test_device_pool_free_list_reuse_never_aliases(uids):
    """Churn a window smaller than the working set so freed pages are
    constantly reallocated to OTHER users: if a recycled page ever
    served stale bytes, the mirror/materialize comparison would catch
    the alias on the very step it appears."""
    host, dev = _drive_pair([("insert", u) for u in uids])
    assert dev.pool.stats["pages_freed"] > 0, "no reuse pressure"
    for uid, he in host.entries.items():
        hv, dv = he.value, dev.entries[uid].value
        if hasattr(hv, "materialize"):
            hk, hvv = hv.materialize()
            dk, dvv = dv.materialize()
            assert hk.tobytes() == dk.tobytes()
            assert hvv.tobytes() == dvv.tobytes()


# ---------------------------------------------------------------------------
# launch-bucket truncation bugfix (_page_launch_args / rank_group)
# ---------------------------------------------------------------------------


def test_page_launch_args_refuses_truncation():
    """The boundary case that used to truncate silently: a table wider
    than the launch bucket must raise, not drop cached pages."""
    import jax.numpy as jnp
    from repro.core.executors import _page_launch_args
    from repro.core.paging import PagedPsi
    buf = np.zeros((9, PT, H, D), np.float32)
    table = np.arange(8, dtype=np.int32).reshape(4, 2)  # 2 pages/slab
    psi = PagedPsi(table, 2 * PT, LAYOUT, buf)
    with pytest.raises(ValueError, match="truncation"):
        _page_launch_args(jnp, [psi], 1)
    # the boundary itself (n == bucket) is fine
    _page_launch_args(jnp, [psi], 2)


# ---------------------------------------------------------------------------
# live end-to-end: device pool == host pool, zero launch re-ships
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def live():
    import jax
    from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
    from repro.models import build_model
    cfg = get_config("hstu_gr", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=16, incr_len=8, max_len=512))
    return cfg, model, params, store


def _live_runtime(live, device_pool):
    cfg, model, params, store = live
    cost = GRCostModel(cfg)
    layout = PageLayout.from_model_config(cfg, 32)
    budget = 64 * layout.entry_bytes(512)
    ex = get_executor("batched")(
        model, params, store, cost=cost,
        batching=BatchingConfig(max_batch=4, max_wait_ms=2.0),
        page_tokens=32, device_pool=device_pool)
    rcfg = relay_config(
        trigger=TriggerConfig(n_instances=2, r2=0.5,
                              kv_p99_len=512, hbm_bytes=budget / 0.5,
                              r1=0.5, t_life_s=5.0, q_m=1e4),
        cluster=ClusterConfig(hbm_cache_bytes=budget,
                              dram_budget_bytes=0.0, max_batch=4,
                              page_tokens=32, device_pool=device_pool,
                              trigger_policy="admit-all",
                              long_seq_threshold=1))
    return RelayRuntime(rcfg, cost, executor_factory=lambda name: ex)


def test_live_device_pool_matches_host_pool_scores(live):
    """THE acceptance: same stream, host-buffer vs device-resident
    deployment — bit-identical scores, and per-launch H2D traffic drops
    from O(pool bytes) to zero."""
    _, _, _, store = live
    metas = [UserMeta(user_id=200 + i,
                      prefix_len=int(store.long_term(200 + i).shape[0]),
                      incr_len=8, n_items=16)
             for i in range(6)]
    results, stats = {}, {}
    for device in (False, True):
        rt = _live_runtime(live, device)
        out = []
        t = 0.0
        for m in metas:
            out.append(rt.submit(m, now=t))
            t += 0.3
        results[device] = out
        stats[device] = rt.stats()["h2d"]
    for hostr, devr in zip(results[False], results[True]):
        assert hostr.hit == devr.hit
        assert hostr.hit == HitKind.HBM_HIT
        assert np.asarray(hostr.scores).tobytes() == \
            np.asarray(devr.scores).tobytes()
    # host-buffer path re-ships the pool once per rank launch...
    assert stats[False]["launch_reships"] >= len(metas)
    assert stats[False]["bytes_scattered"] == 0
    assert not stats[False]["device_resident"]
    # ...the device pool never re-ships, and scatters exactly the
    # freshly inserted page bytes
    h2d = stats[True]
    assert h2d["device_resident"]
    assert h2d["launch_reships"] == 0
    assert h2d["reshipped_bytes"] == 0
    assert h2d["bytes_scattered"] > 0
    layout = PageLayout.from_model_config(live[0], 32)
    # pre_infer pads the prefix to the 64-token prefill grid before the
    # store sizes the entry, so that's the page count that crossed H2D
    inserted = sum(layout.entry_pages(-(-m.prefix_len // 64) * 64)
                   for m in metas)
    assert h2d["pages_scattered"] == inserted
    assert h2d["bytes_scattered"] == inserted * layout.page_bytes


def test_live_rank_group_widens_bucket_past_prefix(live):
    """Regression for the silent truncation: a member whose page table
    overhangs the prefix-derived bucket (whole-page span padding does
    this in segments mode) must gather ALL its pages — the grouped
    launch now scores bit-identically to the per-request launch
    instead of silently dropping the overhanging pages."""
    from repro.serving.batching import PendingRank, bucket_of
    cfg, model, params, store = live
    cost = GRCostModel(cfg)
    ex = get_executor("batched")(
        model, params, store, cost=cost,
        batching=BatchingConfig(max_batch=4), page_tokens=32,
        device_pool=True)
    layout = ex.page_layout
    hbm = PagedHBMStore(64 * layout.entry_bytes(512), layout,
                        device_pool=True)
    hbm.device_hooks = ex
    uid = 7
    meta = UserMeta(user_id=uid, prefix_len=64, incr_len=8, n_items=16)
    kv, _, _ = ex.pre_infer(meta)
    kv = tuple(np.concatenate(
        [np.asarray(a), np.zeros_like(np.asarray(a))], axis=2)
        for a in kv)                       # 128 tokens: 2x the bucket
    hbm.insert(uid, kv, kv_nbytes(kv), 0.0, prefix_len=kv[0].shape[2])
    psi = hbm.acquire_value(hbm.entries[uid])
    assert psi.table.shape[1] > bucket_of(meta.prefix_len) \
        // layout.page_tokens, "fixture must overhang the prefix bucket"
    solo, _ = ex.rank_cached(meta, psi)
    group = [PendingRank(user_id=uid, psi=psi, prefix_len=meta.prefix_len,
                         meta=meta)]
    scores, _ = ex.rank_group(group)
    assert np.asarray(solo).tobytes() == np.asarray(scores[0]).tobytes()
    hbm.release_value(psi)
