"""Paged HBM window — runtime integration contract.

The paged pool must be a drop-in behind the relay lifecycle:

  * sim traces with ``page_tokens > 0`` keep the hit rates of the dense
    window at the same byte budget (page padding is the only waste);
  * an oversized psi is REJECTED, surfaced via ``rejected_inserts`` at
    both store and instance level, and the runtime serves the request
    as a full-inference miss — it never believes psi is resident;
  * partial tail eviction + resumed reload flows through the event
    loop: the resumed DRAM hit pays only the missing pages on the H2D
    channel (``load`` component < a cold reload's);
  * the live ``rank_with_pages`` path — batched executor over a paged
    store, end to end through ``RelayRuntime`` — scores bit-for-bit
    with the dense batched deployment on the same stream.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (BatchingConfig, ClusterConfig, GRCostModel,
                        HitKind, PageLayout, TriggerConfig, UserMeta,
                        get_executor, relay_config)
from repro.core.cache import PagedHBMStore
from repro.core.runtime import RelayRuntime
from repro.models import get_config

COST = GRCostModel(get_config("hstu_gr"))


def _stream(n, qps, L, seed=0, refresh=0.0):
    rng = np.random.default_rng(seed)
    t, out, recent = 0.0, [], []
    while len(out) < n:
        t += rng.exponential(1.0 / qps)
        if recent and rng.random() < refresh:
            uid = int(rng.choice(recent[-500:]))
        else:
            uid = int(rng.integers(0, 10 ** 9))
        recent.append(uid)
        out.append((t, UserMeta(user_id=uid, prefix_len=L)))
    return out


def _cfg(page_tokens, *, hbm=4e9, dram=0.0, max_batch=0, L=2048):
    return relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8,
                              kv_p99_len=max(L, 1024), hbm_bytes=hbm / 0.5,
                              r1=0.5, t_life_s=0.5),
        cluster=ClusterConfig(hbm_cache_bytes=hbm, dram_budget_bytes=dram,
                              page_tokens=page_tokens, max_batch=max_batch))


# ---------------------------------------------------------------------------
# sim parity: paged window == dense window traces
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_batch", [0, 8])
def test_paged_sim_matches_dense_hit_sequence(max_batch):
    """L = 2048 is page-aligned at 64-token pages, so the paged pool
    admits exactly the entries the dense window does: the per-request
    hit sequence is identical and only load times may differ."""
    arr = _stream(300, 80, 2048, seed=2, refresh=0.4)
    outs = {}
    for pt in (0, 64):
        rt = RelayRuntime(_cfg(pt, max_batch=max_batch), COST)
        rt.run(list(arr))
        outs[pt] = [(r.user_id, r.hit) for r in rt.records]
    assert outs[0] == outs[64]


def test_paged_store_selected_and_conserved():
    cfg = _cfg(64, dram=500e9)
    rt = RelayRuntime(cfg, COST)
    rt.run(_stream(200, 120, 1777, seed=1, refresh=0.5))  # unaligned L
    for inst in rt.instances.values():
        assert isinstance(inst.hbm, PagedHBMStore)
        pool = inst.hbm.pool
        assert pool.stats["pages_allocated"] == \
            pool.pages_live + pool.stats["pages_freed"]
        assert inst.hbm.stats["inserts"] == \
            inst.hbm.live_count + inst.hbm.stats["evictions"]


# ---------------------------------------------------------------------------
# oversized psi -> rejection surfaced, served as a miss
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("page_tokens", [0, 64])
def test_oversized_psi_rejected_and_served_as_miss(page_tokens):
    """A window smaller than one psi: the insert is rejected (store +
    instance counters), the pre-parked ranker wakes into a miss, and
    the request completes as a full-inference fallback — the bugfix for
    the silent drop."""
    L = 2048
    tiny = COST.kv_bytes(L) // 2              # half of one psi
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.8,
                              kv_p99_len=L, hbm_bytes=tiny / 0.5, r1=0.5,
                              t_life_s=0.5, q_m=1e4),
        cluster=ClusterConfig(hbm_cache_bytes=tiny, dram_budget_bytes=0.0,
                              page_tokens=page_tokens,
                              trigger_policy="admit-all"))
    rt = RelayRuntime(cfg, COST)
    res = rt.submit(UserMeta(user_id=5, prefix_len=L), now=0.0)
    assert res.hit == HitKind.MISS_FALLBACK
    rejected = sum(i.hbm.stats["rejected_inserts"]
                   for i in rt.instances.values())
    assert rejected >= 1
    assert sum(i.stats["rejected_inserts"]
               for i in rt.instances.values()) == rejected
    # and nothing pretends to be resident
    assert all(i.hbm.live_count == 0 for i in rt.instances.values())


@pytest.mark.parametrize("paged", [False, True])
def test_rejected_refresh_evicts_stale_entry(paged):
    """An oversized same-user REFRESH must not leave the superseded psi
    resident: the stale entry leaves through the eviction turnstile and
    the rejection is still counted (code-review regression)."""
    from repro.core.cache import HBMCacheStore
    layout = PageLayout(page_tokens=8, slabs=4, token_bytes=1)
    store = PagedHBMStore(10 * layout.page_bytes, layout) if paged \
        else HBMCacheStore(10 * layout.page_bytes)
    small = layout.entry_bytes(8)
    store.insert(5, "psi_old", small, 0.0, prefix_len=8)
    store.consume(5)
    huge_tokens = 100 * layout.page_tokens
    evicted = store.insert(5, "psi_new", layout.entry_bytes(huge_tokens),
                           1.0, prefix_len=huge_tokens)
    assert 5 not in store
    assert store.stats["rejected_inserts"] == 1
    assert [e.user_id for e in evicted] == [5]   # stale copy may spill
    assert store.stats["inserts"] == \
        store.live_count + store.stats["evictions"]


def test_unfit_dram_copy_dropped_instead_of_reload_looping():
    """A psi over the WHOLE window budget must never be promoted: the
    expander drops the copy at the cache-check step (one miss, no H2D
    transfer) instead of scheduling a doomed reload per request
    (code-review regression)."""
    from repro.core.cache import CacheEntry, HBMCacheStore
    from repro.core.expander import DRAMExpander, ExpanderConfig
    hbm = HBMCacheStore(10)
    exp = DRAMExpander(ExpanderConfig())
    big = CacheEntry(1, "psi", 20, 0.0, prefix_len=20, consumed=True)
    exp.spill(big)                              # fits DRAM, not HBM
    action, d = exp.pseudo_pre_infer(1, hbm, 1.0)
    exp.finish(1)
    assert action == "miss"
    assert exp.stats["unfit_dropped"] == 1
    assert exp.entries.get(1) is None           # no reload loop possible
    assert exp.stats["reloads"] == 0


def test_transient_reload_rejection_keeps_dram_copy():
    """A promotion rejected only because in-flight launches pin the
    pool (zombie pinch) keeps its DRAM copy — the reload is wasted, psi
    is not — and succeeds once the launch releases its pages
    (code-review regression)."""
    from repro.core.expander import DRAMExpander, ExpanderConfig
    layout = PageLayout(page_tokens=8, slabs=4, token_bytes=1)
    hbm = PagedHBMStore(layout.entry_bytes(16), layout)  # 1-entry pool
    exp = DRAMExpander(ExpanderConfig())
    nbytes = layout.entry_bytes(16)
    hbm.insert(1, "psi", nbytes, 0.0, prefix_len=16)
    hbm.consume(1)
    pinned = hbm.acquire_value(hbm.entries[1])  # in-flight launch
    exp.spill(dataclasses.replace(hbm.entries[1]))
    hbm.pop(1)                                  # whole pool -> zombies
    action, d = exp.pseudo_pre_infer(1, hbm, 2.0)
    assert action == "reload"                   # fits() is about budget
    exp.complete_reload(1, hbm, 3.0)
    exp.finish(1)
    assert hbm.resident(1) is None              # transiently rejected
    assert exp.entries.get(1) is not None       # copy retained
    assert exp.stats["reloads"] == 0            # promotion never landed
    hbm.release_value(pinned)                   # launch completes
    action, d = exp.pseudo_pre_infer(1, hbm, 4.0)
    assert action == "reload"
    exp.complete_reload(1, hbm, 5.0)
    exp.finish(1)
    assert hbm.resident(1) is not None          # retry lands
    assert exp.stats["reloads"] == 1


# ---------------------------------------------------------------------------
# partial eviction -> resumed reload through the event loop
# ---------------------------------------------------------------------------


def test_partial_reload_resumes_through_runtime():
    """Squeeze the window so the oldest consumed DRAM-backed psi loses
    tail pages; its user returns and the DRAM hit's ``load`` component
    prices only the missing pages (cheaper than a cold full reload)."""
    L = 2048
    layout = PageLayout.from_model_config(COST.cfg, 64)
    budget = int(2.5 * layout.entry_bytes(L))  # 2 full psi + change
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=2, r2=0.5,
                              kv_p99_len=L, hbm_bytes=budget / 0.5,
                              r1=0.5, t_life_s=10.0, q_m=1e4),
        cluster=ClusterConfig(hbm_cache_bytes=budget,
                              dram_budget_bytes=500e9, page_tokens=64,
                              trigger_policy="admit-all"))
    rt = RelayRuntime(cfg, COST)
    t = 0.0
    for uid in (1, 2, 3):                      # 3rd insert -> pressure
        rt.submit(UserMeta(user_id=uid, prefix_len=L), now=t)
        t += 1.0
    special = rt.instances["special-0"]
    partial = [e for e in special.hbm.entries.values()
               if e.tokens_resident < e.prefix_len]
    assert special.hbm.stats["partial_evictions"] >= 1
    assert len(partial) == 1
    victim = partial[0]
    missing = victim.prefix_len - victim.tokens_resident
    assert 0 < missing < L
    # rank-path resume (synchronous stage API — no side path to win the
    # race): the DRAM hit's load prices ONLY the missing pages
    from repro.core.types import Request
    res = special.handle_rank(
        Request.rank(999, UserMeta(user_id=victim.user_id, prefix_len=L),
                     now=t), now=t)
    assert res.hit == HitKind.DRAM_HIT
    want = COST.paged_load_ms(missing, 64)
    assert res.components["load"] == pytest.approx(want)
    assert res.components["load"] < COST.paged_load_ms(L, 64)
    assert special.hbm.stats["resumed_reloads"] == 1
    assert special.hbm.entries[victim.user_id].tokens_resident == L
    # event-loop flavour: squeeze again, then let the relay side path
    # resume it ahead of ranking (the race the lifecycle is built for)
    rt.submit(UserMeta(user_id=4, prefix_len=L), now=t + 1.0)
    again = [e for e in special.hbm.entries.values()
             if e.tokens_resident < e.prefix_len]
    if again:                                  # FIFO picked a backed entry
        res2 = rt.submit(UserMeta(user_id=again[0].user_id, prefix_len=L),
                         now=t + 2.0)
        assert res2.hit == HitKind.HBM_HIT     # side path resumed in time
        assert special.hbm.stats["resumed_reloads"] == 2


# ---------------------------------------------------------------------------
# live rank_with_pages == dense batched scores (end to end)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def live():
    import jax
    from repro.data.synthetic import UserBehaviorStore, WorkloadConfig
    from repro.models import build_model
    cfg = get_config("hstu_gr", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = UserBehaviorStore(WorkloadConfig(
        vocab=cfg.vocab, n_items=16, incr_len=8, max_len=512))
    return cfg, model, params, store


def _live_runtime(live, page_tokens):
    cfg, model, params, store = live
    cost = GRCostModel(cfg)
    layout = PageLayout.from_model_config(cfg, page_tokens or 64)
    budget = 64 * layout.entry_bytes(512)
    ex = get_executor("batched")(
        model, params, store, cost=cost,
        batching=BatchingConfig(max_batch=4, max_wait_ms=2.0),
        page_tokens=page_tokens)
    rcfg = relay_config(
        trigger=TriggerConfig(n_instances=2, r2=0.5,
                              kv_p99_len=512, hbm_bytes=budget / 0.5,
                              r1=0.5, t_life_s=5.0, q_m=1e4),
        cluster=ClusterConfig(hbm_cache_bytes=budget,
                              dram_budget_bytes=0.0, max_batch=4,
                              page_tokens=page_tokens,
                              trigger_policy="admit-all",
                              long_seq_threshold=1))
    return RelayRuntime(rcfg, cost, executor_factory=lambda name: ex)


def test_live_rank_with_pages_matches_dense_batched(live):
    """THE live acceptance: the same request stream through (a) the
    dense batched deployment and (b) the paged pool + rank_with_pages
    path produces bit-identical scores and hit kinds."""
    _, _, _, store = live
    metas = [UserMeta(user_id=100 + i,
                      prefix_len=int(store.long_term(100 + i).shape[0]),
                      incr_len=8, n_items=16)
             for i in range(6)]
    results = {}
    for pt in (0, 32):
        rt = _live_runtime(live, pt)
        out = []
        t = 0.0
        for m in metas:
            out.append(rt.submit(m, now=t))
            t += 0.3
        results[pt] = out
    for dense, paged in zip(results[0], results[32]):
        assert dense.hit == paged.hit
        assert dense.hit == HitKind.HBM_HIT
        assert np.asarray(dense.scores).tobytes() == \
            np.asarray(paged.scores).tobytes()


def test_live_paged_warmup_precompiles_rank_with_pages(live):
    cfg, model, params, store = live
    cost = GRCostModel(cfg)
    ex = get_executor("batched")(
        model, params, store, cost=cost,
        batching=BatchingConfig(max_batch=4), page_tokens=32)
    done = ex.warmup([100, 120], batch_sizes=[1, 4], incr_len=8,
                     n_items=16, pool_pages=64)
    assert done, "nothing compiled"
    # the paged entry compiled without error alongside the dense ones;
    # a second warmup is a no-op (keys cached)
    assert ex.warmup([100, 120], batch_sizes=[1, 4], incr_len=8,
                     n_items=16, pool_pages=64) == []
