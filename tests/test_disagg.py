"""Disaggregated prefill: dedicated side-path hosts + cross-host psi
shipping over contended NIC links.

Covers the PR's tentpole semantics end to end:

  * role topology — prefill hosts never own keys; pre-infer signals
    route to the prefill pool while ranking lands on the owner;
  * the shipping lifecycle — prefill compute -> NIC hop -> insert at
    the owning rank instance -> HBM hit, with the trigger pricing the
    hop into its slack test;
  * the shipping-vs-deadline race — a psi landing after its rank
    request is served as a MISS (no stall, no double-rank) and the
    near-miss is counted in ``stats()["shipping"]``;
  * NIC bandwidth accounting — concurrent shipments and rebalance
    migrations serialize on per-host links instead of overlapping for
    free (PR 4's "handoff bandwidth" follow-up).
"""

import dataclasses

import pytest

from repro.core import (ClusterConfig, GRCostModel, HitKind, TriggerConfig,
                        UserMeta, relay_config)
from repro.core.costmodel import HardwareModel
from repro.core.router import AffinityRouter
from repro.core.topology import ClusterTopology, Host, make_prefill_hosts, \
    stripe_hosts
from repro.core.types import Request, Stage
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))


def _cfg(prefill_hosts=1, hosts=2, **cluster):
    return relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.4, kv_p99_len=4096),
        cluster=ClusterConfig(hbm_cache_bytes=1.5e8,
                              dram_budget_bytes=500e9, hosts=hosts,
                              prefill_hosts=prefill_hosts, **cluster))


# ---------------------------------------------------------------------------
# role topology + routing
# ---------------------------------------------------------------------------


def test_owner_map_never_owns_prefill_hosts():
    topo = ClusterTopology(stripe_hosts([f"special-{i}" for i in range(4)],
                                        ["normal-0"], 2)
                           + make_prefill_hosts(2))
    assert topo.all_prefill() == ["prefill-0", "prefill-1"]
    for key in range(500):
        assert topo.owner(key).role != "prefill"
    # a prefill host leave never disturbs the owner map's membership
    before = [topo.owner_map.owner(k) for k in range(100)]
    topo.leave("prefill-host-1")
    assert [topo.owner_map.owner(k) for k in range(100)] == before


def test_cannot_remove_last_rank_host():
    topo = ClusterTopology(stripe_hosts(["special-0"], ["normal-0"], 1)
                           + make_prefill_hosts(1))
    with pytest.raises(ValueError, match="last rank host"):
        topo.leave("host-0")


def test_pre_signals_route_to_prefill_pool_ranks_to_owner():
    topo = ClusterTopology(stripe_hosts([f"special-{i}" for i in range(4)],
                                        ["normal-0"], 2)
                           + make_prefill_hosts(2))
    router = AffinityRouter([f"special-{i}" for i in range(4)],
                            ["normal-0"], topology=topo)
    for uid in range(50):
        meta = UserMeta(user_id=uid, prefix_len=4096)
        pre = router.route(Request.pre_infer(0, meta))
        rank = router.route(Request.rank(1, meta, long_sequence=True))
        assert pre.startswith("prefill-"), pre
        assert rank.startswith("special-"), rank
        assert router.route_pre(uid) == pre     # deterministic
        assert router.route_key(uid) == rank
    assert router.stats["prefill"] == 50


def test_prefill_engines_run_side_path_only():
    sim = ClusterSim(_cfg(), COST)
    arr = [(0.5 * (i + 1), UserMeta(user_id=10 ** 6 + i, prefix_len=2048))
           for i in range(12)]
    s = sim.run(arr)
    pre_insts = {n: i for n, i in sim.runtime.instances.items()
                 if i.role == "prefill"}
    assert pre_insts and all(i.stats["ranks"] == 0
                             for i in pre_insts.values())
    assert sum(i.stats["pre_infers"] for i in pre_insts.values()) == 12
    # ...and the ranking specials ran NO prefill compute: the split
    # frees their slots (the tentpole's capacity argument)
    assert all(i.stats["pre_infers"] == 0
               for n, i in sim.runtime.instances.items()
               if i.role != "prefill")
    assert s["hbm_hit"] == 1.0      # every shipment landed before rank
    assert s["prefill_util"] > 0.0


# ---------------------------------------------------------------------------
# the shipping lifecycle
# ---------------------------------------------------------------------------


def test_shipment_lands_before_rank_and_hits():
    """L=2048: signal (3 ms) + prefill (~25 ms) + NIC hop (~4.7 ms)
    beat the 65 ms retrieval/preprocess slack — the rank request walks
    into an HBM hit with a ZERO pre component (it never parked)."""
    sim = ClusterSim(_cfg(), COST)
    sim.run([(0.0, UserMeta(user_id=7, prefix_len=2048))])
    (rec,) = sim.records
    assert rec.hit == HitKind.HBM_HIT.value
    assert rec.pre_ms == 0.0
    ship = sim.runtime.stats()["shipping"]
    assert ship["shipped"] == ship["landed"] == 1
    assert ship["late_miss"] == 0 and ship["inflight"] == 0
    assert ship["bytes"] == COST.kv_bytes(2048)


def test_shipping_race_served_as_miss_no_stall_no_double_rank():
    """The regression case: at L=4096 the prefill (~82 ms) outlives the
    65 ms slack, so the shipment is still in flight when ranking
    arrives.  Colocated deployments PARK (pre > 0, HBM hit); the
    disaggregated runtime must instead serve the miss immediately —
    no stall on an NIC-contended arrival, exactly one rank — and count
    the near-miss in stats()["shipping"].  The landed psi then serves
    the user's NEXT request as a plain HBM hit."""
    meta = UserMeta(user_id=99, prefix_len=4096)

    colocated = ClusterSim(_cfg(prefill_hosts=0), COST)
    colocated.run([(0.0, meta)])
    assert colocated.records[0].hit == HitKind.HBM_HIT.value
    assert colocated.records[0].pre_ms > 0.0          # parked on its psi

    sim = ClusterSim(_cfg(), COST)
    sim.run([(0.0, meta), (1.0, meta)])
    first, second = sim.records
    assert first.hit == HitKind.MISS_FALLBACK.value
    assert first.pre_ms == 0.0, "the miss must not stall on the wire"
    # no stall: rank-stage wall time is exactly the fallback compute
    assert first.rank_ms == pytest.approx(
        COST.full_rank_ms(4096, meta.incr_len, meta.n_items))
    # no double-rank: one rank per request, nobody was parked
    assert sum(i.stats["ranks"] for i in sim.runtime.instances.values()) \
        == 2
    ship = sim.runtime.stats()["shipping"]
    assert ship["late_miss"] == 1
    assert ship["shipped"] == ship["landed"] == 1
    # the late psi still landed (consumed-on-arrival) and serves the
    # next request
    assert second.hit == HitKind.HBM_HIT.value
    assert sum(i.hbm.stats["premature_evictions"]
               for i in sim.runtime.instances.values()) == 0


def test_trigger_prices_shipping_delay_into_admission():
    """A psi that would arrive after its rank request is useless — with
    a slack budget set, the disaggregated trigger must reject what the
    colocated trigger admits, because the NIC hop eats the window."""
    slow_nic = GRCostModel(get_config("hstu_gr"),
                           hw=HardwareModel(nic_bw=1e7))   # hop ~3.4 s
    meta = UserMeta(user_id=5, prefix_len=2048)
    tcfg = TriggerConfig(n_instances=5, r2=0.4, kv_p99_len=4096,
                         slack_budget_ms=40.0)

    colocated = ClusterSim(relay_config(
        trigger=tcfg, cluster=ClusterConfig(hbm_cache_bytes=1.5e8)),
        slow_nic)
    colocated.run([(0.0, meta)])
    assert colocated.trigger.stats["admitted"] == 1

    disagg = ClusterSim(relay_config(
        trigger=tcfg, cluster=ClusterConfig(hbm_cache_bytes=1.5e8,
                                            hosts=2, prefill_hosts=1)),
        slow_nic)
    disagg.run([(0.0, meta)])
    assert disagg.trigger.stats["admitted"] == 0
    assert disagg.trigger.stats["slack_rejected"] == 1
    assert disagg.runtime.stats()["shipping"]["shipped"] == 0


def test_batched_prefill_groups_and_ships_per_member():
    """Contended prefill engines group admitted users by the prefill
    grid (one jitted launch) and every member ships to its OWN owner."""
    cfg = _cfg(max_batch=4, batch_wait_ms=2.0, m_slots=1)
    cfg = dataclasses.replace(
        cfg, trigger=dataclasses.replace(cfg.trigger, m_slots=1))
    sim = ClusterSim(cfg, COST)
    arr = [(1e-4 * i, UserMeta(user_id=10 ** 5 + i, prefix_len=2048))
           for i in range(6)]
    sim.run(arr)
    batched = [i for i in sim.runtime.instances.values()
               if i.role == "prefill" and i.pre_batcher is not None
               and i.pre_batcher.stats["requests"]]
    assert batched, "no prefill work reached the pre aggregator"
    assert max(i.pre_batcher.stats["max_seen_batch"] for i in batched) > 1
    ship = sim.runtime.stats()["shipping"]
    assert ship["shipped"] == ship["landed"] == 6
    assert ship["inflight"] == 0


def test_reload_completion_closes_stale_shipment_marker():
    """Churn can strand a disagg pre job on its rank owner with the
    shipment marker still open (the prefill pool emptied mid-flight);
    if a local DRAM reload then satisfies it, the marker must close —
    otherwise every later miss for the user is miscounted as a
    late-miss race and ``shipping["inflight"]`` never drains."""
    from repro.core import CacheEntry
    sim = ClusterSim(_cfg(), COST)
    rt = sim.runtime
    uid = 33
    owner = rt.router.route_key(uid)
    inst = rt.instances[owner]
    inst.expander.spill(CacheEntry(uid, "psi", COST.kv_bytes(4096), 0.0,
                                   consumed=True, prefix_len=4096))
    rt._ship_open(uid)      # orphaned marker from the departed engine
    inst.inflight_pre.add(uid)
    inst.enqueue({"kind": "pre",
                  "meta": UserMeta(user_id=uid, prefix_len=4096)}, 0.0)
    rt.drain()
    assert rt.stats()["shipping"]["inflight"] == 0
    assert inst.hbm.resident(uid) is not None


def test_prefill_tier_provisioned_independently():
    """`prefill_m_slots` sizes the dedicated engines (and Eq. 3a's
    per-engine admission rate) independently of the rank tier: a
    prefill engine serving the whole pool's side path must not inherit
    the rank instance's rate cap."""
    sim = ClusterSim(_cfg(prefill_m_slots=20), COST)
    rt = sim.runtime
    (name,) = rt.prefill
    inst = rt.instances[name]
    assert inst.cfg.m_slots == 20
    assert all(rt.instances[s].cfg.m_slots == 5 for s in rt.special)
    # Eq. 3a with the engine's true slot count, bounded by the pool cap
    q_m = sim.cfg.trigger.q_m
    assert rt.trigger.instance_rates[name] == pytest.approx(
        min(q_m * 20, rt.trigger.q_max))
    # the default tier inherits the rank slot count
    plain = ClusterSim(_cfg(), COST).runtime
    (pname,) = plain.prefill
    assert plain.instances[pname].cfg.m_slots == 5
    assert plain.trigger.instance_rates[pname] == pytest.approx(
        min(q_m * 5, plain.trigger.q_max))


# ---------------------------------------------------------------------------
# NIC bandwidth accounting
# ---------------------------------------------------------------------------


def test_concurrent_transfers_contend_for_link_bandwidth():
    """Two transfers leaving the same host at the same instant must
    serialize on its link; transfers between disjoint host pairs stay
    independent.  With serialization off, the legacy latency-only
    pricing is reproduced exactly."""
    rt = ClusterSim(_cfg(), COST).runtime
    assert rt.nic_serialize
    nb = COST.kv_bytes(2048)
    a1, _ = rt._link_transfer(0.0, "src", "dst1", nb, 2048)
    a2, _ = rt._link_transfer(0.0, "src", "dst2", nb, 2048)
    a3, _ = rt._link_transfer(0.0, "other", "dst3", nb, 2048)
    occ_s = COST.link_occupancy_ms(nb) / 1e3
    assert a2 == pytest.approx(a1 + occ_s), "no serialization on src"
    assert a3 == pytest.approx(a1), "disjoint pairs must not contend"
    assert rt.nics["src"]["wait_ms"] > 0.0
    assert rt.nics["src"]["transfers"] == 2

    legacy = ClusterSim(_cfg(nic_serialize=False), COST).runtime
    b1, ms1 = legacy._link_transfer(0.0, "src", "dst1", nb, 2048)
    b2, ms2 = legacy._link_transfer(0.0, "src", "dst2", nb, 2048)
    assert b1 == b2 and ms1 == ms2 == COST.psi_transfer_ms(2048)
    assert legacy.nics == {}


def test_migrations_and_shipments_share_the_unified_pricing():
    """The dedup satellite: rebalance handoffs and psi shipping price
    through ONE GRCostModel entry point, so the two paths cannot
    drift.  ``handoff_ms`` is now an alias of ``psi_transfer_ms``."""
    for L in (512, 2048, 4096):
        assert COST.handoff_ms(L, cross_host=True) \
            == COST.psi_transfer_ms(L, cross_host=True)
        assert COST.handoff_ms(L, cross_host=False) \
            == COST.psi_transfer_ms(L, cross_host=False) \
            == COST.dram_load_ms(L)
        assert COST.psi_transfer_ms(L, cross_host=True) == pytest.approx(
            COST.hw.net_rtt_ms
            + COST.link_occupancy_ms(COST.kv_bytes(L)))


def test_rebalance_migrations_occupy_the_nic():
    """PR 4's follow-up closed: under churn WITH the NIC model on,
    handoff transfers appear on the per-host links (they no longer
    overlap for free)."""
    sim = ClusterSim(_cfg(nic_serialize=True), COST)
    arr = [(0.05 * (i + 1), UserMeta(user_id=2000 + i, prefix_len=2048))
           for i in range(16)]
    sim.runtime.schedule(0.41, "host_leave", name="host-1")
    sim.run(arr)
    rt = sim.runtime
    assert rt.migration["entries"] > 0, "churn found nothing to migrate"
    moved = sum(n["transfers"] for n in rt.nics.values())
    # every cross-host migration and every shipment hits two links
    assert moved >= rt.migration["cross_host"] + \
        rt.stats()["shipping"]["shipped"]
    ship = rt.stats()["shipping"]
    assert ship["shipped"] == ship["landed"] + ship["dropped"]
    assert ship["inflight"] == 0


def test_batched_prefill_coalesces_shipments_per_host():
    """Shipment coalescing: members of ONE batched prefill launch
    bound for the same rank host ride a single NIC transfer (summed
    bytes, one serialization window) instead of serializing per user.
    Fewer NIC transfers than psi shipped, identical payload bytes, and
    — at this operating point — an identical hit profile to the
    unbatched runtime, so amortizing the fabric costs nothing."""
    arr = [(0.001 * i, UserMeta(user_id=3000 + i, prefix_len=2048))
           for i in range(12)]

    def run(**kw):
        sim = ClusterSim(_cfg(nic_serialize=True, prefill_m_slots=2, **kw),
                         COST)
        sim.run(arr)
        return (sim.runtime.stats()["shipping"],
                sorted(r.hit for r in sim.records))

    solo, solo_hits = run()
    batched, batched_hits = run(max_batch=8)

    # the solo path is 1:1 — every shipment is its own transfer
    assert solo["transfers"] == solo["shipped"] == 12
    assert solo["coalesced"] == 0
    # batching coalesces: same psi shipped, strictly fewer transfers
    assert batched["shipped"] == 12
    assert batched["transfers"] < solo["transfers"]
    assert batched["coalesced"] == \
        batched["shipped"] - batched["transfers"]
    # same payload crosses the wire, and nobody's rendezvous regressed
    assert batched["bytes"] == solo["bytes"] == 12 * COST.kv_bytes(2048)
    assert batched_hits == solo_hits
    assert batched["landed"] == solo["landed"] == 12
