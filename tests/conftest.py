import os

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set device_count here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# hypothesis is optional: register the CI profile only when present so
# the suite still collects on minimal environments (the property-based
# tests themselves skip via tests/_hyp.py).
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
