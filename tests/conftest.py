import os

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process; never set device_count here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest
from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
