"""Multi-host topology: owner map, gossip convergence, rebalancing
churn, and the batched side path.

The acceptance contract for the host->instance refactor:

  * ``hosts=1`` reproduces the historical single-process deployment
    bit-for-bit (flat-ring routing, identical live/sim traces);
  * ``hosts>=2`` keeps affinity hit rates within 2% of single-host —
    the two-level rendezvous moves WHERE producer and consumer meet,
    never whether they do;
  * membership churn (host join/leave mid-stream) HANDS OFF resident
    HBM/DRAM entries to their new owners instead of silently losing
    them: ``premature_evictions == 0`` across churn and no user is
    ever resident on two instances (no double-ownership);
  * the owner map is epoch-versioned and the deterministic gossip
    steps converge every host's view after a membership change.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (ClusterConfig, ClusterTopology, GRCostModel,
                        Host, HitKind, OwnerMap, RelayGRService,
                        TriggerConfig, UserMeta, relay_config,
                        stripe_hosts)
from repro.core.router import AffinityRouter, ConsistentHashRing
from repro.core.types import Request
from repro.models import get_config
from repro.serving.simulator import ClusterSim

COST = GRCostModel(get_config("hstu_gr"))


def _arrivals(n=200, seed=0, period=0.02, pool=24, L=4096):
    """Seeded stream with repeat visitors so caches are worth moving."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        uid = int(rng.integers(0, pool))
        out.append((period * (i + 1), UserMeta(user_id=100 + uid,
                                               prefix_len=L)))
    return out


def _cfg(hosts=1, **cluster_kw):
    return relay_config(
        trigger=TriggerConfig(n_instances=10, r2=0.4, kv_p99_len=4096,
                              q_m=100.0),
        cluster=ClusterConfig(hosts=hosts, hbm_cache_bytes=16e9,
                              dram_budget_bytes=500e9, **cluster_kw))


def _premature(sim):
    return sum(i.hbm.stats["premature_evictions"]
               for i in sim.instances.values())


def _assert_single_ownership(sim):
    """No user psi resident on two instances (double-ownership)."""
    seen = {}
    for name, inst in sim.instances.items():
        for uid in inst.hbm.entries:
            assert uid not in seen, \
                f"user {uid} resident on {seen[uid]} AND {name}"
            seen[uid] = name


# ---------------------------------------------------------------------------
# hosts=1 is byte-identical to the historical flat deployment
# ---------------------------------------------------------------------------


def test_single_host_routing_matches_flat_ring():
    special = [f"special-{i}" for i in range(5)]
    normal = [f"normal-{i}" for i in range(5)]
    router = AffinityRouter(special, normal, policy="user_hash")
    flat = ConsistentHashRing(special, vnodes=128)
    for uid in range(2000):
        assert router.route_key(uid) == flat.route(uid)
        req = Request.rank(uid, UserMeta(user_id=uid, prefix_len=64),
                           long_sequence=False)
        assert router.route(req) == normal[uid % len(normal)]
    # the historical compat surface still exists at one host
    assert router.ring.route(7) == flat.route(7)


def test_single_host_trace_identical_to_default():
    """ClusterConfig(hosts=1) IS the default config: the two must
    produce the same object graph and the same trace."""
    a = ClusterSim(_cfg(), COST)
    b = ClusterSim(_cfg(hosts=1), COST)
    a.run(iter(_arrivals()))
    b.run(iter(_arrivals()))
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert (ra.user_id, ra.hit, ra.e2e_ms) == \
            (rb.user_id, rb.hit, rb.e2e_ms)


def test_multi_host_live_and_sim_traces_identical():
    """The live-vs-sim parity contract extends to hosts>=2."""
    cfg = _cfg(hosts=3)
    svc = RelayGRService(cfg, COST)
    live = [svc.submit(meta, now=t) for t, meta in _arrivals(n=80)]
    sim = ClusterSim(cfg, COST)
    sim.run(iter(_arrivals(n=80)))
    assert len(svc.runtime.records) == len(sim.runtime.records) == len(live)
    for a, b, r in zip(svc.runtime.records, sim.runtime.records, live):
        assert a.user_id == b.user_id
        assert a.hit == b.hit == r.hit.value
        for f in ("pre_ms", "load_ms", "rank_ms", "queue_ms"):
            assert getattr(a, f) == pytest.approx(getattr(b, f), abs=1e-9)
        assert r.latency_ms == pytest.approx(sum(r.components.values()),
                                             abs=1e-9)


# ---------------------------------------------------------------------------
# owner map: rendezvous stability + epoch-versioned gossip
# ---------------------------------------------------------------------------


def test_owner_map_join_moves_only_won_keys():
    m3 = OwnerMap([f"host-{i}" for i in range(3)])
    m4 = OwnerMap([f"host-{i}" for i in range(4)])
    keys = range(3000)
    moved = 0
    for k in keys:
        a, b = m3.owner(k), m4.owner(k)
        if a != b:
            assert b == "host-3", \
                "a join may only move keys TO the joining host"
            moved += 1
    # rendezvous: ~1/4 of the keyspace, never a full reshuffle
    assert 0.15 < moved / 3000 < 0.35


def test_owner_map_leave_moves_only_orphans():
    m = OwnerMap([f"host-{i}" for i in range(4)])
    before = {k: m.owner(k) for k in range(2000)}
    m2 = OwnerMap([h for h in m.hosts if h != "host-1"])
    for k, owner in before.items():
        if owner != "host-1":
            assert m2.owner(k) == owner, \
                "a leave may only move the departed host's keys"


def test_gossip_converges_after_churn():
    topo = ClusterTopology(stripe_hosts(
        [f"s{i}" for i in range(8)], [f"n{i}" for i in range(8)], 4))
    assert topo.converged() and topo.epoch == 0
    topo.join(Host("host-9", special=["s9"], normal=["n9"]))
    assert topo.epoch == 1
    assert not topo.converged(), "a join must start from a stale fleet"
    # only the joining host knows the new map; everyone else is stale
    stale = [h for h, v in topo.views.items() if v.epoch == 0]
    assert len(stale) == 4
    rounds = topo.converge()
    assert 0 < rounds <= len(topo.hosts)
    assert topo.converged()
    assert all(v.epoch == 1 for v in topo.views.values())
    # stale views answer consistently DURING convergence too
    topo.leave("host-1")
    assert topo.epoch == 2
    viewer = sorted(topo.hosts)[-1]           # last to hear the rumor
    owner_stale = topo.owner_in_view(viewer, 1234)
    assert owner_stale in ("host-1",) + tuple(topo.hosts) or True
    topo.converge()
    assert topo.owner_in_view(viewer, 1234) == topo.owner_map.owner(1234)


def test_epoch_monotone_and_last_host_protected():
    topo = ClusterTopology([Host("host-0", special=["s0"], normal=["n0"])])
    with pytest.raises(ValueError):
        topo.leave("host-0")
    topo.join(Host("host-1", special=["s1"]))
    topo.leave("host-1")
    assert topo.epoch == 2
    with pytest.raises(ValueError):
        topo.join(Host("host-0"))             # duplicate name


# ---------------------------------------------------------------------------
# rebalancing churn: handoff, no silent loss, no double-ownership
# ---------------------------------------------------------------------------


def test_host_leave_midstream_hands_off_not_loses():
    """The generalized affinity-disruption test: a host leaves mid-
    stream; its entries migrate to the new owners, premature_evictions
    stays 0 cluster-wide, ownership stays single, and the relay keeps
    hitting afterwards."""
    cfg = _cfg(hosts=2)
    sim = ClusterSim(cfg, COST)
    arrivals = _arrivals(n=300)
    t_leave = arrivals[len(arrivals) // 2][0] + 1e-4
    sim.runtime.schedule(t_leave, "host_leave", name="host-1")
    sim.run(iter(arrivals))

    assert _premature(sim) == 0, "churn must never evict unconsumed psi"
    _assert_single_ownership(sim)
    assert sim.runtime.migration["entries"] > 0, \
        "the leave found no entries to hand off (test is vacuous)"
    assert "host-1" not in sim.topology.hosts
    assert sim.topology.epoch == 1
    # after the leave, admitted users must still rendezvous: the tail
    # of the stream (all warm repeat visitors) keeps hitting
    tail = [r for r in sim.records if r.t_arrival > t_leave + 1.0]
    assert tail, "stream ended before the churn settled"
    hit_tail = sum(r.hit != HitKind.MISS_FALLBACK.value for r in tail)
    assert hit_tail / len(tail) > 0.9, \
        f"post-churn hit rate collapsed: {hit_tail}/{len(tail)}"
    assert sim.runtime.migration["dropped"] == 0


def test_host_join_midstream_rebalances_to_new_owner():
    cfg = _cfg(hosts=2)
    sim = ClusterSim(cfg, COST)
    arrivals = _arrivals(n=300)
    t_join = arrivals[len(arrivals) // 2][0] + 1e-4
    sim.runtime.schedule(t_join, "host_join", n_special=2, n_normal=1)
    sim.run(iter(arrivals))

    assert _premature(sim) == 0
    _assert_single_ownership(sim)
    assert sim.topology.epoch == 1 and sim.topology.n_hosts == 3
    new_specials = sim.topology.hosts["host-2"].special
    assert new_specials and all(n in sim.instances for n in new_specials)
    # rendezvous moved ~1/3 of the keyspace to the new host: it must
    # actually serve (received handoffs and/or fresh pre-infers)
    served = sum(sim.instances[n].stats["ranks"] for n in new_specials)
    assert served > 0, "joined host never took ranking traffic"
    tail = [r for r in sim.records
            if r.t_arrival > t_join + 1.0]
    hit_tail = sum(r.hit != HitKind.MISS_FALLBACK.value for r in tail)
    assert hit_tail / max(len(tail), 1) > 0.9


def test_leave_then_join_never_reuses_instance_names():
    """Regression: a join after a leave must mint FRESH instance names —
    reusing a still-live name would silently overwrite that instance
    (and its cache) in the runtime."""
    sim = ClusterSim(_cfg(hosts=2), COST)
    before = set(sim.instances)
    sim.runtime.host_leave("host-1")
    survivors = set(sim.instances)
    host = sim.runtime.host_join(n_special=2, n_normal=1)
    assert not (set(host.instances) & before), \
        f"join reused names: {set(host.instances) & before}"
    assert survivors <= set(sim.instances)
    # every pool name is unique across the topology
    names = [n for h in sim.topology.hosts.values() for n in h.instances]
    assert len(names) == len(set(names))


def test_rebalance_none_models_silent_loss():
    """The ablation knob: rebalance='none' reproduces the naive
    deployment — a leave drops the departed host's caches and the
    affected users fall back (correct result, lost speedup)."""
    cfg = _cfg(hosts=2, rebalance="none")
    sim = ClusterSim(cfg, COST)
    arrivals = _arrivals(n=300)
    t_leave = arrivals[len(arrivals) // 2][0] + 1e-4
    sim.runtime.schedule(t_leave, "host_leave", name="host-1")
    sim.run(iter(arrivals))
    assert sim.runtime.migration["entries"] == 0
    # every request still completes and accounting stays consistent
    assert len(sim.records) == len(arrivals)
    _assert_single_ownership(sim)


def test_multihost_hit_rate_within_two_percent_of_single_host():
    """Steady-state acceptance: hosts=2 affinity hit rates within 2%
    absolute of the identical single-host deployment."""
    rates = {}
    for hosts in (1, 2):
        sim = ClusterSim(_cfg(hosts=hosts), COST)
        s = sim.run(iter(_arrivals(n=400)))
        rates[hosts] = s["hbm_hit"] + s["dram_hit"]
        assert _premature(sim) == 0
    assert abs(rates[1] - rates[2]) <= 0.02, rates


def test_per_host_dram_tier_is_shared_within_host():
    """hosts>=2: instances on one server share the server's DRAM
    expander (DRAM is host memory); hosts=1 keeps the historical
    per-instance tier."""
    multi = ClusterSim(_cfg(hosts=2), COST)
    for host in multi.topology.hosts.values():
        exps = {id(multi.instances[n].expander) for n in host.instances}
        assert len(exps) == 1, "one DRAM tier per host"
    across = {id(multi.instances[h.instances[0]].expander)
              for h in multi.topology.hosts.values()}
    assert len(across) == 2, "hosts must not share DRAM"
    single = ClusterSim(_cfg(hosts=1), COST)
    exps = {id(i.expander) for i in single.instances.values()}
    assert len(exps) == len(single.instances)


# ---------------------------------------------------------------------------
# RandomSpecialRouter: reproducible placement (the ablation bugfix)
# ---------------------------------------------------------------------------


def test_random_router_reproducible_across_processes():
    """Placement derives from (seed, stage, key) — two independently
    constructed routers (≈ two processes) agree call-for-call, and
    repeated calls for one request agree with themselves (the old
    stateful RNG re-rolled every call)."""
    from repro.core.policies import RandomSpecialRouter
    special = [f"special-{i}" for i in range(5)]
    normal = [f"normal-{i}" for i in range(3)]
    a = RandomSpecialRouter(special, normal, seed=3)
    b = RandomSpecialRouter(special, normal, seed=3)
    othseed = RandomSpecialRouter(special, normal, seed=4)
    diff = 0
    for uid in range(300):
        meta = UserMeta(user_id=uid, prefix_len=4096)
        pre = Request.pre_infer(uid, meta)
        rank = Request.rank(uid, meta)
        assert a.route(pre) == b.route(pre) == a.route(pre)
        assert a.route(rank) == b.route(rank)
        diff += a.route(pre) != othseed.route(pre)
    assert diff > 0, "seed must actually vary the placement"
    # pre and rank hash independently: rendezvous only by chance
    hits = sum(a.route(Request.pre_infer(u, UserMeta(u, 4096)))
               == a.route(Request.rank(u, UserMeta(u, 4096)))
               for u in range(300))
    assert hits / 300 < 0.5


def test_random_router_empty_special_pool_degrades_to_normal():
    """Regression: churn emptying the special pool used to crash the
    random ablation with ZeroDivisionError on the empty modulus; keyed
    traffic must instead degrade to the normal-pool path, exactly like
    ``AffinityRouter`` does."""
    from repro.core.policies import RandomSpecialRouter
    r = RandomSpecialRouter(["s0"], ["n0", "n1"], seed=1)
    keyed = Request.rank(1, UserMeta(user_id=7, prefix_len=4096))
    assert r.route(keyed) == "s0"
    # churn takes the last special instance down
    r.topology.hosts["host-0"].special.clear()
    before = r.stats["normal"]
    got = r.route(keyed)
    assert got in ("n0", "n1")
    assert r.stats["normal"] == before + 1
    # deterministic degradation: repeat calls agree
    assert r.route(keyed) == got


# ---------------------------------------------------------------------------
# batched pre-inference (the side path)
# ---------------------------------------------------------------------------


def test_batched_pre_inference_groups_under_contention():
    """A synchronized burst of admitted long-sequence users shares
    jitted prefills: groups deeper than one form, every admitted user
    still ends in a hit, and nothing is evicted prematurely."""
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.4, q_m=200.0,
                              kv_p99_len=4096),
        cluster=ClusterConfig(m_slots=1, max_batch=8, batch_wait_ms=2.0,
                              hbm_cache_bytes=16e9))
    sim = ClusterSim(cfg, COST)
    arrivals = [(0.001 * i, UserMeta(user_id=i, prefix_len=4096))
                for i in range(40)]
    s = sim.run(arrivals)
    stats = [i.pre_batcher.stats for i in sim.instances.values()
             if i.pre_batcher is not None and i.pre_batcher.stats["requests"]]
    assert stats, "no pre-inference was batched"
    assert max(st["max_seen_batch"] for st in stats) > 1, \
        "burst never formed a pre-infer group deeper than 1"
    assert _premature(sim) == 0
    assert s["miss"] < 0.2, f"batched side path lost admissions: {s}"


def test_batched_pre_lifts_admission_throughput():
    """The ROADMAP claim: grouping admitted prefills lifts the side
    path's completion latency under slot contention — the same burst
    finishes strictly earlier than with per-user prefills."""
    def done_at(max_batch):
        cfg = relay_config(
            trigger=TriggerConfig(n_instances=5, r2=0.4, q_m=200.0,
                                  kv_p99_len=4096),
            cluster=ClusterConfig(m_slots=1, max_batch=max_batch,
                                  batch_wait_ms=2.0,
                                  hbm_cache_bytes=16e9))
        sim = ClusterSim(cfg, COST)
        sim.run([(0.001 * i, UserMeta(user_id=i, prefix_len=4096))
                 for i in range(40)])
        assert len(sim.records) == 40
        return max(r.t_done for r in sim.records)

    assert done_at(8) < done_at(0), \
        "batched pre-inference should clear the burst sooner"


# ---------------------------------------------------------------------------
# batch-factor calibration (cost-model loading)
# ---------------------------------------------------------------------------


def test_cost_model_loads_calibration_table(tmp_path):
    import json

    from repro.core.costmodel import load_batch_calibration
    table = {"default": 0.5,
             "buckets": {"256": {"2": 0.1, "8": 0.3},
                         "1024": {"2": 0.2, "8": 0.4}}}
    p = tmp_path / "cal.json"
    p.write_text(json.dumps(table))
    cal = load_batch_calibration(str(p))
    cost = COST.with_calibration(cal)
    # uncalibrated: fixed 0.2
    assert COST.batched_rank_ms([10.0, 10.0]) == pytest.approx(12.0)
    # bucket 256, depth 2 -> 0.1
    assert cost.batched_rank_ms([10.0, 10.0], bucket=256) \
        == pytest.approx(11.0)
    # depth 8 at bucket 1024 -> 0.4
    assert cost.batched_rank_ms([10.0] * 8, bucket=1024) \
        == pytest.approx(10.0 * (1 + 0.4 * 7))
    # depth between measured points uses the deepest measured <= n
    assert cost.batched_rank_ms([10.0] * 4, bucket=256) \
        == pytest.approx(10.0 * (1 + 0.1 * 3))
    # bucket above the table clamps to the largest measured bucket
    assert cost.batched_rank_ms([10.0] * 2, bucket=4096) \
        == pytest.approx(10.0 * (1 + 0.2))
    # singleton launches never pay a factor
    assert cost.batched_rank_ms([10.0], bucket=256) == pytest.approx(10.0)
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.json"
        bad.write_text("{}")
        load_batch_calibration(str(bad))


def test_calibrated_sim_changes_batched_trace_only():
    """Loading a factor table reprices GROUP launches; singleton
    (uncontended) traces are untouched."""
    cal = {"default": 0.05, "buckets": {"4096": {"2": 0.05, "8": 0.05}}}
    cost_cal = COST.with_calibration(cal)
    cfg = relay_config(
        trigger=TriggerConfig(n_instances=5, r2=0.4, q_m=200.0,
                              kv_p99_len=4096),
        cluster=ClusterConfig(m_slots=1, max_batch=8,
                              hbm_cache_bytes=16e9))
    burst = [(0.001 * i, UserMeta(user_id=i, prefix_len=4096))
             for i in range(40)]
    base = ClusterSim(cfg, COST)
    base.run(list(burst))
    cheap = ClusterSim(cfg, cost_cal)
    cheap.run(list(burst))
    t_base = max(r.t_done for r in base.records)
    t_cheap = max(r.t_done for r in cheap.records)
    assert t_cheap < t_base, \
        "a cheaper measured factor must speed the contended trace up"
