"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attn import decode_attn
from repro.kernels.hstu_attn import hstu_attn
from repro.kernels.paged_prefix_attn import (pack_pages, pack_segments,
                                             paged_prefix_rank_attn,
                                             segment_rank_attn)
from repro.kernels.prefix_rank_attn import prefix_rank_attn

RNG = np.random.default_rng(7)


def _mk(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(atol=3e-4, rtol=3e-4),
       jnp.bfloat16: dict(atol=6e-2, rtol=6e-2)}


@pytest.mark.parametrize("S,bq,bk", [(128, 128, 128), (256, 128, 64),
                                     (512, 256, 256), (1024, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("D", [64, 128])
def test_hstu_attn_sweep(S, bq, bk, dtype, D):
    B, H = 2, 2
    q, k, v = (_mk((B, H, S, D), dtype) for _ in range(3))
    out = hstu_attn(q, k, v, bq=bq, bk=bk, interpret=True)
    want = ref.hstu_attn_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("n_prefix,n_incr,n_items",
                         [(128, 64, 64), (256, 64, 192), (512, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_rank_attn_sweep(n_prefix, n_incr, n_items, dtype):
    B, H, D = 2, 2, 64
    Sq, Sk = n_incr + n_items, n_prefix + n_incr + n_items
    q = _mk((B, H, Sq, D), dtype)
    k = _mk((B, H, Sk, D), dtype)
    v = _mk((B, H, Sk, D), dtype)
    out = prefix_rank_attn(q, k, v, n_prefix=n_prefix, n_incr=n_incr,
                           bq=64, bk=64, interpret=True)
    want = ref.prefix_rank_attn_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), n_prefix=n_prefix, n_incr=n_incr)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def _paged_case(plens, bucket, pt, n_incr, n_items, dtype, seed=3):
    """Build matched dense/paged inputs: dense psi zero-padded to the
    bucket (what the bucketed batched path feeds prefix_rank_attn) and
    the same prefixes sliced into pool pages + page tables."""
    rng = np.random.default_rng(seed)
    B, H, D = len(plens), 2, 64
    Sq = n_incr + n_items
    mk = lambda *s: rng.normal(size=s).astype(np.float32)
    q, kn, vn = (jnp.asarray(mk(B, H, Sq, D), dtype) for _ in range(3))
    kp = np.zeros((B, H, bucket, D), np.float32)
    vp = np.zeros_like(kp)
    for b, p in enumerate(plens):
        kp[b, :, :p], vp[b, :, :p] = mk(H, p, D), mk(H, p, D)
    kp, vp = jnp.asarray(kp, dtype), jnp.asarray(vp, dtype)
    kpg, vpg, table, pl_ = pack_pages(kp, vp, plens, pt,
                                      n_pages=bucket // pt)
    return q, kp, vp, kn, vn, (jnp.asarray(kpg), jnp.asarray(vpg),
                               jnp.asarray(table), jnp.asarray(pl_))


@pytest.mark.parametrize("n_prefix,pt,n_incr,n_items",
                         [(128, 64, 32, 32), (256, 64, 32, 32),
                          (256, 128, 64, 64)])
def test_paged_rank_attn_bitwise_aligned(n_prefix, pt, n_incr, n_items):
    """Page-aligned prefixes: the paged kernel's two-phase accumulation
    chain reproduces the dense kernel (bk = page_tokens) BIT FOR BIT."""
    q, kp, vp, kn, vn, paged = _paged_case(
        [n_prefix, n_prefix], n_prefix, pt, n_incr, n_items, jnp.float32)
    k = jnp.concatenate([kp, kn], axis=2)
    v = jnp.concatenate([vp, vn], axis=2)
    want = prefix_rank_attn(q, k, v, n_prefix=n_prefix, n_incr=n_incr,
                            bq=32, bk=pt, interpret=True)
    got = paged_prefix_rank_attn(q, *paged, kn, vn, n_incr=n_incr,
                                 bq=32, bk=pt, interpret=True)
    assert np.asarray(got).tobytes() == np.asarray(want).tobytes()


@pytest.mark.parametrize("plens,bucket", [([100, 37, 128], 128),
                                          ([1, 200, 64], 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_rank_attn_mixed_lengths(plens, bucket, dtype):
    """Mixed per-row prefix lengths in ONE launch — the occupancy win
    paging buys — match the dense kernel on zero-padded psi to fp32
    tolerance (and still bitwise for f32: silu(0) pad keys contribute
    exactly nothing on both sides)."""
    pt, n_incr, n_items = 64, 32, 32
    Sq = n_incr + n_items
    q, kp, vp, kn, vn, paged = _paged_case(
        plens, bucket, pt, n_incr, n_items, dtype)
    k = jnp.concatenate([kp, kn], axis=2)
    v = jnp.concatenate([vp, vn], axis=2)
    want = prefix_rank_attn(q, k, v, n_prefix=bucket, n_incr=n_incr,
                            bq=32, bk=pt, n_total=bucket + Sq,
                            interpret=True)
    got = paged_prefix_rank_attn(q, *paged, kn, vn, n_incr=n_incr,
                                 bq=32, bk=pt, n_total=bucket + Sq,
                                 interpret=True)
    if dtype == jnp.float32:
        assert np.asarray(got).tobytes() == np.asarray(want).tobytes()
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_rank_attn_matches_oracle():
    """Independent of the dense kernel: gather pages back to dense and
    check against the pure-numpy reference oracle."""
    pt, n_incr, n_items = 64, 16, 48
    plens, bucket = [90, 128], 128
    q, kp, vp, kn, vn, paged = _paged_case(
        plens, bucket, pt, n_incr, n_items, jnp.float32)
    Sq = n_incr + n_items
    k = jnp.concatenate([kp, kn], axis=2)
    v = jnp.concatenate([vp, vn], axis=2)
    want = ref.prefix_rank_attn_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), n_prefix=bucket, n_incr=n_incr)
    got = paged_prefix_rank_attn(q, *paged, kn, vn, n_incr=n_incr,
                                 bq=32, bk=pt, n_total=bucket + Sq,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL[jnp.float32])


def _segment_case(patterns, n_items, pt, dtype, seed=11, n_pages=None):
    """Build matched interleaved inputs from per-row chunk patterns.

    ``patterns[b]`` is an ordered list of ('c', ln) cached-span /
    ('f', ln) fresh-token chunks; every row must carry the same total
    fresh count Sq and end with at least ``n_items`` fresh tokens (the
    candidate items occupy the sequence tail).  Returns the fresh-token
    q/k/v, the span-aware pool pack, the FULL dense interleaved
    sequence (positions 0..S_b-1 per row, padded rows masked by a
    sentinel position) and the position arrays — everything both the
    kernel and the dense interleaved oracle need."""
    rng = np.random.default_rng(seed)
    B, H, D = len(patterns), 2, 64
    SENTINEL = 1 << 20
    Sq = sum(ln for kind, ln in patterns[0] if kind == "f")
    spans, fpos, totals = [], [], []
    for row in patterns:
        assert sum(ln for kind, ln in row if kind == "f") == Sq
        assert row[-1][0] == "f" and row[-1][1] >= n_items
        pos, sp, fp = 0, [], []
        for kind, ln in row:
            if kind == "c":
                sp.append((pos, ln))
            else:
                fp.extend(range(pos, pos + ln))
            pos += ln
        spans.append(sp)
        fpos.append(fp)
        totals.append(pos)
    S_max = max(totals)
    k_full = rng.normal(size=(B, H, S_max, D)).astype(np.float32)
    v_full = rng.normal(size=(B, H, S_max, D)).astype(np.float32)
    k_pos = np.full((B, S_max), SENTINEL, np.int32)
    for b, S_b in enumerate(totals):
        k_pos[b, :S_b] = np.arange(S_b)
    q = rng.normal(size=(B, H, Sq, D)).astype(np.float32)
    q_pos = np.asarray(fpos, np.int32)
    idx = q_pos[:, None, :, None]
    kn = np.take_along_axis(k_full, np.broadcast_to(
        idx, (B, H, Sq, D)), axis=2)
    vn = np.take_along_axis(v_full, np.broadcast_to(
        idx, (B, H, Sq, D)), axis=2)
    C_max = max(sum(ln for _, ln in sp) for sp in spans)
    kc = np.zeros((B, H, C_max, D), np.float32)
    vc = np.zeros_like(kc)
    for b, sp in enumerate(spans):
        off = 0
        for start, ln in sp:
            kc[b, :, off:off + ln] = k_full[b, :, start:start + ln]
            vc[b, :, off:off + ln] = v_full[b, :, start:start + ln]
            off += ln
    paged = pack_segments(kc, vc, spans, pt, n_pages=n_pages)
    to = lambda x: jnp.asarray(x, dtype)
    return (to(q), to(kn), to(vn),
            tuple(jnp.asarray(p) for p in paged), jnp.asarray(q_pos),
            to(k_full), to(v_full), jnp.asarray(k_pos))


@pytest.mark.parametrize("plens,bucket", [([128, 128], 128),
                                          ([100, 37, 128], 128)])
def test_segment_rank_attn_prefix_only_bitwise(plens, bucket):
    """Degenerate interleaving (one span at [0, prefix_len), fresh
    tokens after it): the segment kernel's masks reduce to the prefix
    kernel's, so it reproduces ``paged_prefix_rank_attn`` — and through
    it the dense reference chain — BIT FOR BIT.  This is the
    segments-disabled parity discipline at the kernel level."""
    pt, n_incr, n_items = 64, 32, 32
    Sq = n_incr + n_items
    q, kp, vp, kn, vn, paged = _paged_case(
        plens, bucket, pt, n_incr, n_items, jnp.float32)
    want = paged_prefix_rank_attn(q, *paged, kn, vn, n_incr=n_incr,
                                  bq=32, bk=pt, n_total=bucket + Sq,
                                  interpret=True)
    # same prefixes as single spans in the segment layout
    spans = [[(0, int(p))] for p in plens]
    kc = np.zeros((len(plens), 2, bucket, 64), np.float32)
    vc = np.zeros_like(kc)
    for b, p in enumerate(plens):
        kc[b, :, :p] = np.asarray(kp, np.float32)[b, :, :p]
        vc[b, :, :p] = np.asarray(vp, np.float32)[b, :, :p]
    seg = tuple(jnp.asarray(x) for x in
                pack_segments(kc, vc, spans, pt, n_pages=bucket // pt))
    q_pos = jnp.asarray(np.asarray(plens, np.int32)[:, None]
                        + np.arange(Sq, dtype=np.int32)[None])
    got = segment_rank_attn(q, *seg, q_pos, kn, vn, n_items=n_items,
                            bq=32, bk=pt, n_total=bucket + Sq,
                            interpret=True)
    assert np.asarray(got).tobytes() == np.asarray(want).tobytes()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_rank_attn_matches_interleaved_oracle(dtype):
    """Beyond-prefix reuse: cached interior segments interleaved with
    fresh tokens (different layouts per row, one launch) match the
    dense reference built from the same interleaving — fresh tokens
    between two cached segments must NOT see the later segment."""
    pt, n_items = 64, 32
    patterns = [
        [("c", 64), ("f", 32), ("c", 64), ("f", 32)],
        [("c", 30), ("f", 10), ("c", 50), ("f", 22), ("c", 17),
         ("f", 32)],
    ]
    q, kn, vn, seg, q_pos, k_full, v_full, k_pos = _segment_case(
        patterns, n_items, pt, dtype)
    Sq = q.shape[2]
    n_pages = seg[2].shape[1]
    nt = n_pages * pt + Sq
    got = segment_rank_attn(q, *seg, q_pos, kn, vn, n_items=n_items,
                            bq=32, bk=pt, n_total=nt, interpret=True)
    want = ref.segment_rank_attn_ref(
        q.astype(jnp.float32), k_full.astype(jnp.float32),
        v_full.astype(jnp.float32), q_pos=q_pos, k_pos=k_pos,
        n_items=n_items, n_total=nt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_segment_ref_degenerates_to_prefix_ref():
    """The interleaved oracle itself: one span at [0, P) + fresh tokens
    after it equals the prefix oracle exactly (same mask bits)."""
    P, n_incr, n_items = 96, 16, 48
    B, H, D = 2, 2, 64
    Sq = n_incr + n_items
    rng = np.random.default_rng(23)
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    q, k, v = mk(B, H, Sq, D), mk(B, H, P + Sq, D), mk(B, H, P + Sq, D)
    want = ref.prefix_rank_attn_ref(q, k, v, n_prefix=P, n_incr=n_incr)
    q_pos = np.broadcast_to(P + np.arange(Sq, dtype=np.int32), (B, Sq))
    k_pos = np.broadcast_to(np.arange(P + Sq, dtype=np.int32),
                            (B, P + Sq))
    got = ref.segment_rank_attn_ref(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                    n_items=n_items)
    assert np.asarray(got).tobytes() == np.asarray(want).tobytes()


def test_rank_mask_matches_model():
    """Kernel mask semantics == model-level rank_mask (candidate
    independence is the correctness-critical property)."""
    from repro.models.hstu import rank_mask
    m_model = np.asarray(rank_mask(8, 4, 6)[0, 0])
    m_ref = np.asarray(ref.rank_mask_ref(8, 4, 6))
    np.testing.assert_array_equal(m_model, m_ref)
    # items never attend to other items
    qi = np.arange(10)[:, None]
    ki = np.arange(18)[None, :]
    item_q, item_k = qi >= 4, ki >= 12
    cross_item = m_ref & item_q & item_k & (ki != qi + 8)
    assert not cross_item.any()


@pytest.mark.parametrize("S,KV,H", [(1024, 2, 8), (2048, 4, 4),
                                    (4096, 1, 8), (512, 8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_sweep(S, KV, H, dtype):
    B, D = 2, 64
    q = _mk((B, H, D), dtype)
    k = _mk((B, KV, S, D), dtype)
    v = _mk((B, KV, S, D), dtype)
    out = decode_attn(q, k, v, bk=256, interpret=True)
    want = ref.decode_attn_ref(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_ops_wrappers_model_layout():
    B, S, H, D = 2, 256, 2, 64
    q, k, v = (_mk((B, S, H, D), jnp.float32) for _ in range(3))
    out = ops.hstu_attention(q, k, v)
    want = jnp.swapaxes(ref.hstu_attn_ref(*(jnp.swapaxes(t, 1, 2)
                                            for t in (q, k, v))), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-4, rtol=3e-4)
    # odd sizes fall back to the oracle path without error
    qo, ko, vo = (_mk((B, 100, H, D), jnp.float32) for _ in range(3))
    assert ops.hstu_attention(qo, ko, vo).shape == (B, 100, H, D)


@pytest.mark.parametrize("H,P,N", [(4, 64, 64), (2, 128, 32), (8, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_kernel_sweep(H, P, N, dtype):
    from repro.kernels.ssd_chunk import ssd_chunk_intra, ssd_chunk_intra_ref
    B, nc, Q = 2, 2, 128
    Cc = _mk((B, nc, Q, N), dtype)
    Bc = _mk((B, nc, Q, N), dtype)
    xc = _mk((B, nc, Q, H, P), dtype)
    cum = jnp.asarray(-np.abs(RNG.normal(size=(B, nc, Q, H))).cumsum(2),
                      jnp.float32)
    dtc = jnp.asarray(np.abs(RNG.normal(size=(B, nc, Q, H))), jnp.float32)
    out = ssd_chunk_intra(Cc, Bc, xc, cum, dtc, interpret=True)
    ref = ssd_chunk_intra_ref(Cc.astype(jnp.float32),
                              Bc.astype(jnp.float32),
                              xc.astype(jnp.float32), cum, dtc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("H,P,N", [(4, 64, 64), (2, 128, 32)])
def test_ssd_chunk_state_kernel(H, P, N):
    from repro.kernels.ssd_chunk import ssd_chunk_state, ssd_chunk_state_ref
    B, nc, Q = 2, 2, 128
    Bc = _mk((B, nc, Q, N), jnp.float32)
    xc = _mk((B, nc, Q, H, P), jnp.float32)
    cum = jnp.asarray(-np.abs(RNG.normal(size=(B, nc, Q, H))).cumsum(2),
                      jnp.float32)
    dtc = jnp.asarray(np.abs(RNG.normal(size=(B, nc, Q, H))), jnp.float32)
    out = ssd_chunk_state(Bc, xc, cum, dtc, interpret=True)
    ref = ssd_chunk_state_ref(Bc, xc, cum, dtc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-4, rtol=3e-4)
