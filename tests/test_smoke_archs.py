"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED same-family variant
(2 layers, d_model <= 512, <= 4 experts) and runs one forward/train step
plus one prefill+decode step on CPU, asserting output shapes and no
NaNs.  Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.steps import make_train_step
from repro.models import ARCH_IDS, get_model
from repro.models.config import InputShape
from repro.training import optimizer as opt

B, S = 2, 32


def _batch(model):
    cfg = model.cfg
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["frontend"] = jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_model(arch, smoke=True).cfg
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    model = get_model(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(model)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    step, _, _ = make_train_step(
        model, InputShape("t", S, B, "train"),
        opt.AdamWConfig(warmup_steps=1, total_steps=10))
    state = opt.init_state(params)
    p2, s2, m = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(s2["step"]) == 1
    # params actually moved
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert d0.shape == d1.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_step(arch):
    model = get_model(arch, smoke=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(model)
    pf_batch = ({k: v for k, v in batch.items() if k != "labels"})
    logits, cache = jax.jit(model.prefill)(params, pf_batch)
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_padded
    dbatch = {"token": jnp.ones((B, 1), jnp.int32),
              "pos": jnp.full((B,), S - 1, jnp.int32)}
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, dbatch)
    assert logits2.shape == (B, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache pytree structure is stable under decode
    assert (jax.tree.structure(cache) == jax.tree.structure(cache2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_match_prefill(arch):
    model = get_model(arch, smoke=True)
    sds, axes = model.cache_specs(B, S)
    assert jax.tree.structure(sds, is_leaf=lambda x: hasattr(x, "shape")) \
        is not None
    flat = [s for s in jax.tree.leaves(sds)]
    assert all(hasattr(s, "shape") and hasattr(s, "dtype") for s in flat)
