"""The paper's correctness contract (§2.3):

    | f([U, Sl, S~, I], 0) - f([0, 0, S~, I], psi) | <= eps

Ranking with the pre-inferred prefix cache psi must reproduce full-
inference scores.  Verified for the HSTU backbone (the GR family RelayGR
serves) and, for the generic-LM architectures, as prefill+decode vs
full-forward logits equivalence (the same psi-reuse semantics their
serve path relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_model
from repro.models.hstu import rank_mask

EPS = 2e-4


def test_hstu_rank_with_cache_matches_monolithic():
    model = get_model("hstu_gr", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B, n_prefix, n_incr, n_items = 2, 64, 16, 32
    prefix = jnp.asarray(rng.integers(0, 500, (B, n_prefix)), jnp.int32)
    incr = jnp.asarray(rng.integers(0, 500, (B, n_incr)), jnp.int32)
    items = jnp.asarray(rng.integers(0, 500, (B, n_items)), jnp.int32)

    # relay path: pre-infer psi, then rank on cache
    _, psi = model.prefill(params, {"tokens": prefix})
    scores_relay = model.rank_with_cache(params, psi, incr, items)

    # monolithic path: one forward over [prefix|incr|items] with the same
    # ranking mask (items independent), no cache
    from repro.models.arch import _embed
    x = _embed(params, jnp.concatenate([prefix, incr, items], axis=1))
    positions = jnp.arange(x.shape[1])[None, :]
    mask = rank_mask(0, n_prefix + n_incr, n_items)
    h, _ = model._run(params, x, positions, mask)
    items_h = h[:, n_prefix + n_incr:]
    tw = params["task_tower"]
    ht = jax.nn.silu(jnp.einsum("bsd,df->bsf", items_h, tw["w1"]))
    scores_full = jnp.einsum("bsf,ft->bst", ht, tw["w2"])

    err = float(jnp.abs(scores_relay - scores_full).max())
    assert err <= EPS, f"relay deviates from full inference: {err}"


def test_hstu_full_rank_path():
    model = get_model("hstu_gr", smoke=True)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(4)
    B = 2
    prefix = jnp.asarray(rng.integers(0, 500, (B, 64)), jnp.int32)
    incr = jnp.asarray(rng.integers(0, 500, (B, 16)), jnp.int32)
    items = jnp.asarray(rng.integers(0, 500, (B, 32)), jnp.int32)
    _, psi = model.prefill(params, {"tokens": prefix})
    a = model.rank_with_cache(params, psi, incr, items)
    b = model.full_rank(params, prefix, incr, items)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=EPS, rtol=EPS)
    assert a.shape == (B, 32, model.cfg.n_tasks)


@pytest.mark.parametrize("arch", ["qwen3_4b", "yi_9b", "internvl2_2b"])
def test_lm_prefill_decode_matches_full_forward(arch):
    """Generic LM psi-reuse: logits from prefill(P)+decode(token) equal
    full prefill(P+1) last-token logits."""
    model = get_model(arch, smoke=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    B, P = 2, 15
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, P + 1)), jnp.int32)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :P]}
    if cfg.family == "vlm":
        fe = jnp.asarray(rng.normal(size=(B, cfg.n_frontend_tokens,
                                          cfg.d_model)), jnp.float32)
        batch_full["frontend"] = fe
        batch_pre["frontend"] = fe
    full_logits, _ = model.prefill(params, batch_full)

    _, kv = model.prefill(params, batch_pre)
    # place prefix KV into a ring cache of size Pk+1, decode at pos Pk
    # (VLM prefixes include the frontend patch tokens)
    Pk = P + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    k, v = kv
    L, _, _, KV, D = k.shape
    ck = jnp.zeros((L, B, Pk + 1, KV, D), k.dtype).at[:, :, :Pk].set(k)
    cv = jnp.zeros((L, B, Pk + 1, KV, D), v.dtype).at[:, :, :Pk].set(v)
    step_logits, _ = model.decode_step(
        params, (ck, cv),
        {"token": toks[:, P:], "pos": jnp.full((B,), Pk, jnp.int32)})

    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, 0], np.float32), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["rwkv6_1p6b", "zamba2_1p2b"])
def test_ssm_state_relay_matches_full_forward(arch):
    """SSM/hybrid psi is the recurrent state: prefill(P)+decode(token)
    must equal full forward — the paper's technique applied to
    attention-free families (DESIGN.md §Arch-applicability)."""
    model = get_model(arch, smoke=True)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(6)
    B = 2
    # mamba chunking: P multiple of chunk not required for decode path
    P = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, P + 1)), jnp.int32)
    full_logits, _ = model.prefill(params, {"tokens": toks})
    _, state = model.prefill(params, {"tokens": toks[:, :P]})
    if arch.startswith("zamba"):
        # pad shared-attn kv cache by one slot for the new token
        a = state["a"]
        k, v = a
        Lh = k.shape[0]
        ck = jnp.zeros((Lh, B, P + 1) + k.shape[3:], k.dtype
                       ).at[:, :, :P].set(k)
        cv = jnp.zeros((Lh, B, P + 1) + v.shape[3:], v.dtype
                       ).at[:, :, :P].set(v)
        state = {"m": state["m"], "a": (ck, cv)}
    step_logits, _ = model.decode_step(
        params, state,
        {"token": toks[:, P:], "pos": jnp.full((B,), P, jnp.int32)})
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, 0], np.float32), atol=2e-3, rtol=2e-3)
