"""Quickstart: the RelayGR relay in 50 lines.

Builds the HSTU GR backbone, pre-infers a user's long-term behaviour
prefix (psi), relays it through the HBM sliding-window cache, and
scores candidates with `rank_with_cache` — asserting the paper's
epsilon-equivalence against full inference — then prints the window's
stats ledger (the same unified counter family every cache tier
reports: inserts / live / evictions / handoffs + extras).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import HBMCacheStore, kv_nbytes
from repro.models import get_model

model = get_model("hstu-gr", smoke=True)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

# a user's behaviour stream: long-term prefix | short-term | candidates
prefix = jnp.asarray(rng.integers(0, 500, (1, 128)), jnp.int32)
incr   = jnp.asarray(rng.integers(0, 500, (1, 16)), jnp.int32)
items  = jnp.asarray(rng.integers(0, 500, (1, 32)), jnp.int32)

# 1) relay-race side path (during retrieval): pre-infer psi
_, psi = jax.jit(model.prefill)(params, {"tokens": prefix})
kv_mb = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(psi)) / 2**20
print(f"psi: per-layer KV cache, {kv_mb:.2f} MiB for 128 tokens")

# 2) the relay baton: psi waits in the HBM sliding window until the
#    ranking request arrives (T_life-bounded in production)
window = HBMCacheStore(budget_bytes=64 * 2 ** 20)
window.insert(user_id=1, value=psi, nbytes=kv_nbytes(psi), now=0.0,
              prefix_len=prefix.shape[1])
psi_cached = window.lookup(1).value
window.consume(1)                       # ranking takes the baton

# 3) fine-grained ranking (later, same instance): reuse psi
scores_relay = model.rank_with_cache(params, psi_cached, incr, items)

# 4) the paper's correctness contract: |relay - full| <= eps
scores_full = model.full_rank(params, prefix, incr, items)
err = float(jnp.abs(scores_relay - scores_full).max())
print(f"scores: {scores_relay.shape}, |relay - full| = {err:.2e}")
assert err < 1e-4
print("relay-race inference == full inference (eps-bound holds)")

# 5) the window's ledger: the unified counter family (inserts == live
#    + evictions + handoffs; every tier in the hierarchy reports the
#    same core, see src/repro/core/README.md)
print("hbm window ledger:",
      {k: window.stats[k] for k in ("inserts", "hits", "misses",
                                    "evictions", "handoffs")},
      f"live={window.live_count}")
