"""Capacity planning with the paper's admission-control equations.

Sweeps the trigger knobs (r1, r2, M, T_life) and prints the derived
live-cache cap L, per-instance admitted QPS and pool-wide Q_max
(Eqs. 1-3), validates the chosen operating point in the discrete-event
cluster simulator, then rebuilds the same point with the full memory
hierarchy (HBM window -> DRAM expander -> cold store) under a
rapid-refresh stream and prints the unified per-tier stats ledger —
every tier reports the same counter core (inserts / live / evictions /
handoffs [+ demotions / promotions]), so the table reads as one
conserved flow down and back up the hierarchy.

Run:  PYTHONPATH=src python examples/cluster_capacity.py
"""
import numpy as np

from repro.core import (ClusterConfig, GRCostModel, SequenceAwareTrigger,
                        TriggerConfig, UserMeta, relay_config)
from repro.data.synthetic import UserBehaviorStore, request_stream
from repro.models import get_config
from repro.serving.simulator import ClusterSim, run_sim

cost = GRCostModel(get_config("hstu-gr"))
print("r1   M   T_life   L(cap)  Q_admit/inst  Q_max(pool)")
for r1 in (0.25, 0.5):
    for m in (3, 5):
        for t_life in (0.2, 0.4):
            cfg = TriggerConfig(r1=r1, m_slots=m, t_life_s=t_life)
            trig = SequenceAwareTrigger(cfg, cost)
            s = trig.summary()
            print(f"{r1:.2f} {m:3d} {t_life:6.1f}   "
                  f"{s['live_cache_cap_L']:7.0f} {s['q_admit_per_instance']:12.0f} "
                  f"{s['q_max_pool']:12.0f}")

print("\nvalidating r1=0.5, M=5 at 300 QPS in the cluster sim:")
store = UserBehaviorStore()
arr = request_stream(store, 300, 15.0)
s = run_sim(relay_config(trigger=TriggerConfig(n_instances=10)), cost, arr)
print({k: round(v, 3) for k, v in s.items() if k in
       ("p99_ms", "success_rate", "goodput_qps", "hbm_hit", "miss")})

# --- the full memory hierarchy under tail pressure --------------------------
# Small HBM window + small DRAM expander + big cold store, driven by a
# 90%-recurring pool wider than both warm tiers: psi demotes down the
# hierarchy on LRU pressure and promotes back on return visits.
print("\nmemory hierarchy (HBM -> DRAM -> cold) under a recurring pool:")
trig = TriggerConfig(n_instances=5, r2=0.8, t_life_s=0.5, kv_p99_len=4096,
                     hbm_bytes=4e9, r1=0.5,
                     q_m=1e3 / cost.pre_infer_ms(3072))
sim = ClusterSim(relay_config(trigger=trig, cluster=ClusterConfig(
    hbm_cache_bytes=300e6, dram_budget_bytes=150e6,
    cold_budget_bytes=400e9)), cost)
rng = np.random.default_rng(7)
pool, t, arrivals = [1000 + i for i in range(60)], 0.0, []
for _ in range(400):
    t += rng.exponential(1 / 60.0)
    uid = (int(rng.choice(pool)) if rng.random() < 0.9
           else int(rng.integers(0, 10 ** 9)))
    arrivals.append((t, UserMeta(user_id=uid, prefix_len=2048)))
summary = sim.run(iter(arrivals))
print({k: round(summary[k], 3)
       for k in ("hbm_hit", "dram_hit", "cold_hit", "miss")})

stats = sim.runtime.stats()
CORE = ("inserts", "live", "evictions", "demotions", "handoffs",
        "promotions")
print(f"\n{'tier':<16}" + "".join(f"{c:>11}" for c in CORE))
for name, inst in stats["instances"].items():
    for tier in ("hbm", "dram"):
        row = inst[tier]
        print(f"{name}/{tier:<{16 - len(name) - 1}}"
              + "".join(f"{row.get(c, 0):>11}" for c in CORE))
for host, row in stats["cold"]["stores"].items():
    print(f"{host}/cold      "
          + "".join(f"{row.get(c, 0):>11}" for c in CORE))
ledger = {k: v for k, v in stats["cold"].items() if k != "stores"}
print("\ncold runtime ledger:", ledger)
