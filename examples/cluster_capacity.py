"""Capacity planning with the paper's admission-control equations.

Sweeps the trigger knobs (r1, r2, M, T_life) and prints the derived
live-cache cap L, per-instance admitted QPS and pool-wide Q_max
(Eqs. 1-3), then validates the chosen operating point in the
discrete-event cluster simulator.

Run:  PYTHONPATH=src python examples/cluster_capacity.py
"""
from repro.core import (GRCostModel, SequenceAwareTrigger, TriggerConfig,
                        relay_config)
from repro.data.synthetic import UserBehaviorStore, request_stream
from repro.models import get_config
from repro.serving.simulator import run_sim

cost = GRCostModel(get_config("hstu-gr"))
print("r1   M   T_life   L(cap)  Q_admit/inst  Q_max(pool)")
for r1 in (0.25, 0.5):
    for m in (3, 5):
        for t_life in (0.2, 0.4):
            cfg = TriggerConfig(r1=r1, m_slots=m, t_life_s=t_life)
            trig = SequenceAwareTrigger(cfg, cost)
            s = trig.summary()
            print(f"{r1:.2f} {m:3d} {t_life:6.1f}   "
                  f"{s['live_cache_cap_L']:7.0f} {s['q_admit_per_instance']:12.0f} "
                  f"{s['q_max_pool']:12.0f}")

print("\nvalidating r1=0.5, M=5 at 300 QPS in the cluster sim:")
store = UserBehaviorStore()
arr = request_stream(store, 300, 15.0)
s = run_sim(relay_config(trigger=TriggerConfig(n_instances=10)), cost, arr)
print({k: round(v, 3) for k, v in s.items() if k in
       ("p99_ms", "success_rate", "goodput_qps", "hbm_hit", "miss")})
