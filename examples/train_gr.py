"""Train the GR backbone on the synthetic next-item-prediction pipeline
(a few hundred steps, CPU-sized model), logging the training ledger —
loss / grad-norm / lr / s-per-step every --log-every steps — and writing a
checkpoint the serving examples can reload.

Run:  PYTHONPATH=src python examples/train_gr.py
Production shapes go through repro.launch.dryrun / the production mesh.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or
         ["--arch", "hstu-gr", "--smoke", "--steps", "200",
          "--batch", "8", "--seq", "128", "--ckpt", "/tmp/relaygr_ck/hstu"])
