"""Train the GR backbone on the synthetic next-item-prediction pipeline
(a few hundred steps, CPU-sized model).

Run:  PYTHONPATH=src python examples/train_gr.py
Production shapes go through repro.launch.dryrun / the production mesh.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or
         ["--arch", "hstu-gr", "--smoke", "--steps", "200",
          "--batch", "8", "--seq", "128", "--ckpt", "/tmp/relaygr_ck/hstu"])
