"""End-to-end driver (the paper's kind: serving): boots a live RelayGR
service — sequence-aware trigger, affinity router, HBM window, DRAM
expander, optional cold store, all orchestrated by the shared
event-driven RelayRuntime — over a real jitted HSTU model and replays a
batched synthetic request stream through the full
retrieval->preprocess->rank relay, printing the hit breakdown and the
trigger's admission ledger (plus the shipping / cold ledgers when those
tiers are enabled).

Run:  PYTHONPATH=src python examples/serve_relay.py [--requests 100]

The same launcher exposes every serving axis (see --help):

  --sim                         virtual-clock cluster sim at prod QPS
  --batched --max-batch 8       continuous micro-batching
  --page-tokens 64 --segments   paged window + beyond-prefix reuse
  --hosts 2 --prefill-hosts 1   multi-host + disaggregated prefill
  --dram-budget 4e9 --cold-budget 500e9   DRAM + SSD/remote cold tier

Also: PYTHONPATH=src python -m repro.launch.serve --sim   (cluster sim)
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--requests", "100", "--qps", "150"])
