"""End-to-end driver (the paper's kind: serving): boots a live RelayGR
service — sequence-aware trigger, affinity router, HBM window, DRAM
expander, all orchestrated by the shared event-driven RelayRuntime —
over a real jitted HSTU model and replays a batched synthetic request
stream through the full retrieval->preprocess->rank relay.

Run:  PYTHONPATH=src python examples/serve_relay.py [--requests 100]
Also: PYTHONPATH=src python -m repro.launch.serve --sim   (cluster sim)
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--requests", "100", "--qps", "150"])
